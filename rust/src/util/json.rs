//! Minimal JSON parser/serializer for the artifact manifest.
//!
//! The sandbox has no `serde`/`serde_json`, so this is a small, strict,
//! dependency-free implementation: full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null), `f64` numbers, and a
//! pretty/compact writer. It only needs to round-trip `manifest.json`
//! (written by `python/compile/aot.py` via the stdlib `json` module), but is
//! a complete parser and is property-tested for parse∘write = id.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic — the Rust↔Python agreement tests diff serialized text.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style chained lookup; panics with the path on miss.
    /// Manifest reads go through this so a schema mismatch fails loudly.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("manifest: missing key {key:?} in {self:.60?}"))
    }

    /// Build an object from `(key, value)` pairs — the convenience
    /// constructor shared by the bench/report emitters.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>; panics on shape mismatch (manifest bug).
    pub fn num_vec(&self) -> Vec<f64> {
        self.as_arr()
            .expect("expected array")
            .iter()
            .map(|v| v.as_f64().expect("expected number"))
            .collect()
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.num_vec().into_iter().map(|f| f as usize).collect()
    }

    // ---- writer -----------------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no Infinity/NaN tokens: Rust's `{}` formatting
                    // would emit `inf`/`NaN` and poison every downstream
                    // parse of the document. Refuse, degrading the one value
                    // to `null` (what Python's json.dumps calls
                    // allow_nan=False semantics, minus the exception).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: JSON from Python never emits
                            // unpaired surrogates for our manifests; handle
                            // the pair case anyway.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.len() < self.i + 11
                                    || self.b[self.i + 5] != b'\\'
                                    || self.b[self.i + 6] != b'u'
                                {
                                    return Err(self.err("unpaired surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.i + 7..self.i + 11],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                                self.i += 6;
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

    /// Validate a value's grammar and advance past it without building it.
    /// The lazy-scan contract: skipped values still get the *full* grammar
    /// check (a malformed sibling fails the scan), they just never allocate
    /// a tree.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.eat(b'{')?;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected , or }")),
                    }
                }
            }
            Some(b'[') => {
                self.eat(b'[')?;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected , or ]")),
                    }
                }
            }
            Some(b'"') => self.string().map(|_| ()),
            Some(b't') => self.lit("true", Json::Null).map(|_| ()),
            Some(b'f') => self.lit("false", Json::Null).map(|_| ()),
            Some(b'n') => self.lit("null", Json::Null).map(|_| ()),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(|_| ()),
            _ => Err(self.err("expected value")),
        }
    }

    /// Parse a `[num, num, ...]` array straight into `Vec<f32>` — no
    /// `Json::Arr` of boxed `Json::Num`s in between. `key` only labels the
    /// error message.
    fn f32_array(&mut self, key: &str) -> Result<Vec<f32>, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            self.ws();
            match self.peek() {
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    if let Json::Num(n) = self.number()? {
                        out.push(n as f32);
                    }
                }
                _ => {
                    return Err(JsonError {
                        offset: self.i,
                        msg: format!("{key}[{}] is not a number", out.len()),
                    })
                }
            }
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(out);
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }
}

/// Lazily extract one numeric-array field from a top-level JSON object,
/// decoded straight to `Vec<f32>`, without building the document tree.
///
/// This is the serving front door's JSON ingestion path: at ResNet-18
/// geometry an infer body is a ~150k-element array, and a full-tree parse
/// allocates a boxed `Json::Num` per element only to throw the tree away.
/// The scanner walks the same grammar but materializes *only* `key`'s
/// array.
///
/// Contract (matched by property tests against [`Json::parse`]):
/// * The whole document is still grammar-checked — skipped siblings and
///   trailing garbage fail the scan exactly as they fail a full parse.
/// * `Ok(None)` when the document is valid JSON but is not an object, has
///   no `key` member, or `key`'s value is not an array — the caller's
///   "missing field" case.
/// * On duplicate keys the last occurrence wins, matching `Json::parse`'s
///   map-insert semantics.
/// * A non-numeric array element is an error naming the index, not `None`.
pub fn extract_f32_field(s: &str, key: &str) -> Result<Option<Vec<f32>>, JsonError> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let mut found = None;
    if p.peek() == Some(b'{') {
        p.eat(b'{')?;
        p.ws();
        if p.peek() == Some(b'}') {
            p.i += 1;
        } else {
            loop {
                p.ws();
                let k = p.string()?;
                p.ws();
                p.eat(b':')?;
                p.ws();
                if k == key && p.peek() == Some(b'[') {
                    found = Some(p.f32_array(key)?);
                } else {
                    p.skip_value()?;
                    if k == key {
                        found = None;
                    }
                }
                p.ws();
                match p.peek() {
                    Some(b',') => p.i += 1,
                    Some(b'}') => {
                        p.i += 1;
                        break;
                    }
                    _ => return Err(p.err("expected , or }")),
                }
            }
        }
    } else {
        // Not an object: still insist the body is valid JSON so garbage
        // reports a parse error rather than a missing field.
        p.skip_value()?;
    }
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(found)
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.at("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\\ 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\\ 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":null},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn num_vec_accessors() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.num_vec(), vec![1.0, 2.0, 3.0]);
        assert_eq!(v.usize_vec(), vec![1, 2, 3]);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null_and_roundtrip() {
        // Regression: `format!("{}", f64::INFINITY)` is `inf`, which is not
        // a JSON token — a single +inf summary field (empty latency track)
        // made the whole BENCH_serving.json unparseable.
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        let doc = Json::obj(vec![
            ("ok", Json::Num(1.5)),
            ("poisoned", Json::Num(f64::INFINITY)),
        ]);
        let text = doc.to_string_compact();
        let back = Json::parse(&text).expect("document with a non-finite member must stay parseable");
        assert_eq!(back.get("poisoned"), Some(&Json::Null));
        assert_eq!(back.get("ok").and_then(|v| v.as_f64()), Some(1.5));
    }

    // ---- property tests: parse ∘ write = id over random documents --------

    fn random_json(r: &mut crate::util::Rng, depth: usize) -> Json {
        let choices = if depth == 0 { 4 } else { 6 };
        match r.below(choices) {
            0 => Json::Null,
            1 => Json::Bool(r.bool(0.5)),
            2 => {
                // Mix integers and fractions; avoid NaN/inf (not JSON).
                if r.bool(0.5) {
                    Json::Num((r.next_u64() % 100_000) as f64 - 50_000.0)
                } else {
                    Json::Num((r.f64() - 0.5) * 1e6)
                }
            }
            3 => {
                let len = r.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        match r.below(6) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => 'é',
                            4 => '😀',
                            _ => (b'a' + r.below(26) as u8) as char,
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => {
                let len = r.below(5);
                Json::Arr((0..len).map(|_| random_json(r, depth - 1)).collect())
            }
            _ => {
                let len = r.below(5);
                let mut m = BTreeMap::new();
                for i in 0..len {
                    m.insert(format!("k{i}_{}", r.below(100)), random_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_documents() {
        crate::util::prop::forall(
            111,
            256,
            |r| random_json(r, 3),
            |doc| {
                let text = doc.to_string_compact();
                let back = Json::parse(&text)
                    .map_err(|e| format!("re-parse failed: {e} on {text:?}"))?;
                // Numbers may lose ulps through the f64->text->f64 trip;
                // compare with tolerance via a normalized serialization.
                crate::util::prop::ensure(
                    json_close(&back, doc),
                    || format!("roundtrip mismatch:\n{doc:?}\n{back:?}"),
                )
            },
        );
    }

    fn json_close(a: &Json, b: &Json) -> bool {
        match (a, b) {
            (Json::Num(x), Json::Num(y)) => {
                (x - y).abs() <= 1e-9 * (1.0 + x.abs().max(y.abs()))
            }
            (Json::Arr(x), Json::Arr(y)) => {
                x.len() == y.len() && x.iter().zip(y).all(|(p, q)| json_close(p, q))
            }
            (Json::Obj(x), Json::Obj(y)) => {
                x.len() == y.len()
                    && x.iter().zip(y).all(|((ka, va), (kb, vb))| {
                        ka == kb && json_close(va, vb)
                    })
            }
            _ => a == b,
        }
    }

    #[test]
    fn obj_builds_from_pairs() {
        let j = Json::obj(vec![("b", Json::Num(1.0)), ("a", Json::Bool(true))]);
        assert_eq!(j.get("a"), Some(&Json::Bool(true)));
        assert_eq!(j.get("b").and_then(|v| v.as_f64()), Some(1.0));
    }

    // ---- lazy field scanner ----------------------------------------------

    #[test]
    fn scanner_extracts_field_and_skips_siblings() {
        let doc = r#"{"meta": {"a": [1, "x"], "b": "br]ack{et"}, "image": [1, -2.5, 3e2], "z": null}"#;
        let got = extract_f32_field(doc, "image").unwrap();
        assert_eq!(got, Some(vec![1.0, -2.5, 300.0]));
        assert_eq!(extract_f32_field(r#"{"image": []}"#, "image").unwrap(), Some(vec![]));
    }

    #[test]
    fn scanner_reports_missing_field_as_none() {
        // Valid JSON without the field — in every spelling — is None, the
        // caller's "missing field" case, not an error.
        assert_eq!(extract_f32_field(r#"{"other": [1]}"#, "image").unwrap(), None);
        assert_eq!(extract_f32_field(r#"{"image": 5}"#, "image").unwrap(), None);
        assert_eq!(extract_f32_field(r#"{"image": "x"}"#, "image").unwrap(), None);
        assert_eq!(extract_f32_field("[1, 2]", "image").unwrap(), None);
        assert_eq!(extract_f32_field("null", "image").unwrap(), None);
        assert_eq!(extract_f32_field("{}", "image").unwrap(), None);
    }

    #[test]
    fn scanner_errors_name_the_bad_element() {
        let err = extract_f32_field(r#"{"image": [1, "x", 3]}"#, "image").unwrap_err();
        assert!(err.msg.contains("image[1]"), "{err}");
        let err = extract_f32_field(r#"{"image": [1, null]}"#, "image").unwrap_err();
        assert!(err.msg.contains("image[1]"), "{err}");
    }

    #[test]
    fn scanner_still_grammar_checks_the_whole_document() {
        // Malformed siblings and trailing garbage fail the scan even though
        // their values are never materialized.
        assert!(extract_f32_field(r#"{"image": [1], "bad": nul}"#, "image").is_err());
        assert!(extract_f32_field(r#"{"image": [1]} extra"#, "image").is_err());
        assert!(extract_f32_field(r#"{"image": [1],}"#, "image").is_err());
        assert!(extract_f32_field(r#"{"image""#, "image").is_err());
    }

    #[test]
    fn scanner_duplicate_key_matches_full_parse_last_wins() {
        let doc = r#"{"image": [1], "image": [2, 3]}"#;
        assert_eq!(extract_f32_field(doc, "image").unwrap(), Some(vec![2.0, 3.0]));
        let doc = r#"{"image": [1], "image": false}"#;
        assert_eq!(extract_f32_field(doc, "image").unwrap(), None);
    }

    #[test]
    fn prop_scanner_agrees_with_full_parse() {
        // Scanner twin of the roundtrip property: embed a random numeric
        // array among random siblings; the lazy scan must read back exactly
        // what a full-tree parse reads.
        crate::util::prop::forall(
            113,
            256,
            |r| {
                let n = r.below(30);
                let vals: Vec<f32> =
                    (0..n).map(|_| ((r.f64() - 0.5) * 1e4) as f32).collect();
                let mut m = BTreeMap::new();
                m.insert(
                    "image".to_string(),
                    Json::Arr(vals.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                m.insert("sib".to_string(), random_json(r, 2));
                (Json::Obj(m).to_string_compact(), vals)
            },
            |(text, vals)| {
                let got = extract_f32_field(text, "image")
                    .map_err(|e| format!("scan failed: {e} on {text:?}"))?;
                let full: Vec<f32> = Json::parse(text)
                    .map_err(|e| e.to_string())?
                    .at("image")
                    .num_vec()
                    .into_iter()
                    .map(|v| v as f32)
                    .collect();
                crate::util::prop::ensure(
                    got.as_deref() == Some(&vals[..]) && full == vals[..],
                    || format!("scan {got:?} / full {full:?} != {vals:?}"),
                )
            },
        );
    }

    #[test]
    fn prop_scanner_never_panics_on_garbage() {
        crate::util::prop::forall(
            114,
            512,
            |r| {
                let len = r.below(40);
                (0..len)
                    .map(|_| {
                        let c = r.below(96) as u8 + 32;
                        c as char
                    })
                    .collect::<String>()
            },
            |s| {
                let _ = extract_f32_field(s, "image"); // must not panic
                Ok(())
            },
        );
    }

    #[test]
    fn prop_parser_never_panics_on_garbage() {
        // Arbitrary byte soup must return Err, not panic.
        crate::util::prop::forall(
            112,
            512,
            |r| {
                let len = r.below(40);
                (0..len)
                    .map(|_| {
                        let c = r.below(96) as u8 + 32;
                        c as char
                    })
                    .collect::<String>()
            },
            |s| {
                let _ = Json::parse(s); // must not panic
                Ok(())
            },
        );
    }
}
