//! Offline substrates: JSON, PRNG, property-testing, CLI, statistics.
//!
//! This sandbox has no network access to crates.io, so the usual
//! `serde_json`/`rand`/`proptest`/`clap`/`criterion` stack is replaced by
//! these small, fully tested implementations (see DESIGN.md §5).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
