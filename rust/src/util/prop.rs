//! Mini property-based testing harness (no `proptest` offline).
//!
//! `forall(seed, cases, gen, prop)` samples `cases` inputs from `gen` and
//! checks `prop` on each. On failure it retries the *same* input a second
//! time (to rule out flaky environment effects), then panics with the case
//! index and the RNG seed that reproduces it — rerun with
//! `FORALL_SEED=<seed> cargo test <name>` to replay.
//!
//! This intentionally skips shrinking: generators here produce small,
//! readable cases (the failure message includes `Debug` of the input), which
//! in practice is what we debug from.

use super::rng::Rng;
use std::fmt::Debug;

/// Default number of cases per property (override with FORALL_CASES).
pub const DEFAULT_CASES: usize = 128;

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok()?.parse().ok()
}

/// Check `prop` on `cases` random inputs drawn via `gen`.
///
/// `prop` returns `Err(msg)` to fail with a message (preferred over
/// panicking inside, so the harness can attach the seed/case context).
pub fn forall<T: Debug>(
    base_seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let seed = env_u64("FORALL_SEED").unwrap_or(base_seed);
    let cases = env_u64("FORALL_CASES").map(|c| c as usize).unwrap_or(cases);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}/{cases}, seed {seed}): {msg}\n\
                 input: {input:#?}\n\
                 replay: FORALL_SEED={seed} FORALL_CASES={n}",
                n = case + 1,
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol + 1e-6 * y.abs() {
            return Err(format!("{what}: elem {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

/// `Ok(())` iff `cond`, else the formatted message — property helper.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 64, |r| r.below(100), |&n| ensure(n < 100, || format!("{n}")));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_seed_in_message() {
        forall(2, 64, |r| r.below(10), |&n| ensure(n < 5, || format!("n={n}")));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.5], 0.1, "t").is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-8], 0.1, "t").is_ok());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 0.1, "t").is_err());
    }
}
