//! Deterministic PRNG (xoshiro256**) + distributions.
//!
//! The sandbox has no `rand` crate; everything stochastic in the coordinator,
//! the property-test harness, and the workload generators draws from this.
//! xoshiro256** is small, fast, and passes BigCrush — more than enough for
//! load generation and property sampling (this is *not* a crypto RNG).

/// xoshiro256** seeded via splitmix64 (so any u64 seed is a good seed).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state; avoids the all-zeros fixed point.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi.saturating_sub(lo).max(1))
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with rate `lambda` (Poisson-process inter-arrivals for the
    /// serving workload generator).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (stream split for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
