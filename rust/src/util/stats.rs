//! Statistics helpers: summary stats, percentiles, and a streaming timer
//! used by the bench harness and the serving metrics.

use std::time::{Duration, Instant};

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Population variance of an f32 slice (matches numpy's default `var`),
/// used by the scheme-assignment policy (row variance).
pub fn variance_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated percentile (q in [0, 100]) of an unsorted slice.
///
/// NaN-tolerant: samples are ordered with `f64::total_cmp`, so one poisoned
/// latency record degrades that record's rank (NaN sorts above +inf) instead
/// of panicking inside a metrics snapshot the way `partial_cmp().unwrap()`
/// did. Callers needing several percentiles of the same data should sort
/// once and use [`percentile_sorted`] (what [`Summary::of`] does).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// [`percentile`] over a slice already sorted with `f64::total_cmp`.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Latency/throughput summary of a sample set (durations in seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            // An empty track has no extrema; the unguarded folds returned
            // min=+inf / max=-inf, which leaked as non-JSON `inf` tokens
            // into every serialized report. All-zero is the sentinel.
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                min: 0.0,
                max: 0.0,
            };
        }
        // One sort serves all three percentiles — a metrics scrape builds
        // five summaries over up-to-64k-sample tracks, so the historic
        // three-clones-three-sorts-per-summary was real CPU on the
        // `/v1/metrics` path. min/max keep the NaN-ignoring folds (a NaN
        // sample sorts to the end under total_cmp and would masquerade as
        // the max).
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: stddev(&v),
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
            min: v.iter().copied().fold(f64::INFINITY, f64::min),
            max: v.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Machine-readable form shared by every report emitter (`loadgen
    /// --out`, `BENCH_serving.json`, the `/v1/metrics` HTTP endpoint).
    /// Durations are in seconds, matching the recorded samples.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean_s", Json::Num(self.mean)),
            ("p50_s", Json::Num(self.p50)),
            ("p95_s", Json::Num(self.p95)),
            ("p99_s", Json::Num(self.p99)),
            ("min_s", Json::Num(self.min)),
            ("max_s", Json::Num(self.max)),
        ])
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            self.n,
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.p99 * 1e3
        )
    }
}

/// Measure `f` `iters` times after `warmup` throwaway runs; returns per-call
/// seconds. The criterion stand-in used by the `harness = false` benches.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Wall-clock stopwatch with named laps (used by the e2e driver logs).
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 0.01);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn variance_matches_numpy_semantics() {
        // numpy: np.var([1,2,3,4]) == 1.25 (population variance)
        assert!((variance_f32(&[1.0, 2.0, 3.0, 4.0]) - 1.25).abs() < 1e-9);
        assert_eq!(variance_f32(&[]), 0.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_all_finite_zeros() {
        // Regression: min/max used to come back +inf/-inf and leak non-JSON
        // `inf` tokens into BENCH_serving.json / `loadgen --out`.
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!((s.min, s.max), (0.0, 0.0));
        assert!(
            [s.mean, s.std, s.p50, s.p95, s.p99, s.min, s.max]
                .iter()
                .all(|v| v.is_finite())
        );
        // The serialized form must round-trip through the strict parser.
        let text = s.to_json().to_string_compact();
        assert!(!text.contains("inf"), "non-JSON token in {text}");
        let back = crate::util::Json::parse(&text).expect("empty summary must serialize as valid JSON");
        assert_eq!(back.get("min_s").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: `partial_cmp().unwrap()` panicked on the first NaN
        // sample, killing the whole metrics snapshot. With total_cmp the
        // NaN sorts above +inf and only pollutes the top ranks.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        let p50 = percentile(&xs, 50.0);
        assert!(p50.is_finite(), "median of mostly-finite samples: {p50}");
        assert!((p50 - 2.5).abs() < 1e-9, "NaN must rank last: {p50}");
        // All-NaN degrades to NaN without panicking.
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let samples = bench(2, 5, || count += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(count, 7);
    }
}
