//! Statistics helpers: summary stats, percentiles, and a streaming timer
//! used by the bench harness and the serving metrics.

use std::time::{Duration, Instant};

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Population variance of an f32 slice (matches numpy's default `var`),
/// used by the scheme-assignment policy (row variance).
pub fn variance_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated percentile (q in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Latency/throughput summary of a sample set (durations in seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            self.n,
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.p99 * 1e3
        )
    }
}

/// Measure `f` `iters` times after `warmup` throwaway runs; returns per-call
/// seconds. The criterion stand-in used by the `harness = false` benches.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Wall-clock stopwatch with named laps (used by the e2e driver logs).
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 0.01);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn variance_matches_numpy_semantics() {
        // numpy: np.var([1,2,3,4]) == 1.25 (population variance)
        assert!((variance_f32(&[1.0, 2.0, 3.0, 4.0]) - 1.25).abs() < 1e-9);
        assert_eq!(variance_f32(&[]), 0.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let samples = bench(2, 5, || count += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(count, 7);
    }
}
