//! Poison-tolerant lock acquisition.
//!
//! The serving stack contains worker threads that may die by panic (the
//! fault-injection backend's whole product is injected panics). A panicking
//! thread poisons any `std` lock it holds, and every later `.lock().unwrap()`
//! on another thread then panics too — one contained fault cascades into a
//! dead server. The supervision layers (watchdog, quarantine, breaker) are
//! built on the opposite assumption: a dead worker is survivable.
//!
//! `plock`/`pread`/`pwrite` acquire the guard whether or not the lock is
//! poisoned. This is sound for our state because every critical section
//! leaves the protected data consistent at each await-free step boundary
//! (counters, swap-gated `Option<Server>` slots, breaker state machines);
//! there is no multi-step invariant that a mid-section panic can tear.
//!
//! These also keep the serving path clean under the `ilmpq analyze` R1 rule
//! (no `unwrap`/`expect` in `coordinator/`/`backend/`): lock acquisition is
//! the one place where `unwrap` was both pervasive and wrong.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Poison-tolerant `Mutex` acquisition.
pub trait LockExt<T> {
    /// Lock, recovering the guard from a poisoned mutex instead of panicking.
    fn plock(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Poison-tolerant `RwLock` acquisition.
pub trait RwLockExt<T> {
    /// Read-lock, recovering the guard from a poisoned lock.
    fn pread(&self) -> RwLockReadGuard<'_, T>;
    /// Write-lock, recovering the guard from a poisoned lock.
    fn pwrite(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn pread(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(|e| e.into_inner())
    }

    fn pwrite(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.plock();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*m.plock(), 7);
    }

    #[test]
    fn pread_pwrite_survive_poison() {
        let l = Arc::new(RwLock::new(3u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.pwrite();
            panic!("poison the lock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*l.pread(), 3);
        *l.pwrite() = 4;
        assert_eq!(*l.pread(), 4);
    }
}
