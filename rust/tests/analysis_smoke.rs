//! `ilmpq analyze` smoke tests: every rule fires on a bad fixture and is
//! silent on its good twin, the pragma machinery suppresses with a reason
//! and fails without one, and — the point of the exercise — the real crate
//! source comes back clean. The runtime twin (`Metrics::audit`) gets the
//! same treatment: a deliberately imbalanced ledger must be rejected.

use std::path::Path;

use ilmpq::analysis::{analyze, render_text, report_json, Project};
use ilmpq::coordinator::Metrics;
use ilmpq::util::Json;

fn findings_for(files: &[(&str, &str)]) -> Vec<String> {
    let p = Project::from_memory(files);
    analyze(&p).into_iter().map(|f| format!("{}:{} {}", f.path, f.line, f.rule)).collect()
}

fn rules_for(files: &[(&str, &str)]) -> Vec<&'static str> {
    let p = Project::from_memory(files);
    analyze(&p).into_iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------- R1

#[test]
fn r1_fires_on_serving_path_unwrap_and_panic() {
    let bad = "fn f() { x.unwrap(); }\nfn g() { y.expect(\"set\"); }\nfn h() { panic!(\"no\"); }";
    assert_eq!(
        findings_for(&[("coordinator/server.rs", bad)]),
        vec![
            "coordinator/server.rs:1 R1",
            "coordinator/server.rs:2 R1",
            "coordinator/server.rs:3 R1"
        ]
    );
}

#[test]
fn r1_silent_on_good_twin() {
    let good = "fn f() -> Result<()> { let v = x.ok_or(ServeError::ShuttingDown)?; Ok(v) }";
    assert!(rules_for(&[("coordinator/server.rs", good)]).is_empty());
    // Same text out of scope: also silent.
    assert!(rules_for(&[("util/misc.rs", "fn f() { x.unwrap(); }")]).is_empty());
}

#[test]
fn r1_ignores_test_code() {
    let src = "#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { x.unwrap(); panic!(\"boom\"); }\n}";
    assert!(rules_for(&[("backend/cpu.rs", src)]).is_empty());
}

// ---------------------------------------------------------------- R2

#[test]
fn r2_fires_on_dropped_send_result() {
    let bad = "fn f(tx: &Sender<u8>) { let _ = tx.send(1); }";
    assert_eq!(rules_for(&[("coordinator/server.rs", bad)]), vec!["R2"]);
}

#[test]
fn r2_silent_on_handled_send_and_out_of_scope() {
    let good = "fn f(tx: &Sender<u8>) { if tx.send(1).is_err() { count(); } }";
    assert!(rules_for(&[("coordinator/server.rs", good)]).is_empty());
    let bad = "fn f(tx: &Sender<u8>) { let _ = tx.send(1); }";
    assert!(rules_for(&[("coordinator/loadgen.rs", bad)]).is_empty());
}

// ---------------------------------------------------------------- R3

const SERVER_WITH_ENUM: &str =
    "pub enum ServeError { QueueFull, InvalidInput(String), ShuttingDown }";

#[test]
fn r3_fires_on_unmapped_variant() {
    let http = "fn status(e: &ServeError) -> u16 { match e { ServeError::QueueFull => 429, ServeError::InvalidInput(_) => 400, _ => 500 } }";
    let loadgen = "fn fold(e: &ServeError) { match e { ServeError::QueueFull => shed(), ServeError::InvalidInput(_) => invalid(), ServeError::ShuttingDown => drain() } }";
    let rules = rules_for(&[
        ("coordinator/server.rs", SERVER_WITH_ENUM),
        ("coordinator/http.rs", http),
        ("coordinator/loadgen.rs", loadgen),
    ]);
    // ShuttingDown is missing from http.rs only.
    assert_eq!(rules, vec!["R3"]);
}

#[test]
fn r3_silent_when_every_variant_is_mapped() {
    let both = "fn m(e: &ServeError) { match e { ServeError::QueueFull => a(), ServeError::InvalidInput(_) => b(), ServeError::ShuttingDown => c() } }";
    let rules = rules_for(&[
        ("coordinator/server.rs", SERVER_WITH_ENUM),
        ("coordinator/http.rs", both),
        ("coordinator/loadgen.rs", both),
    ]);
    assert!(rules.is_empty(), "{rules:?}");
}

// ---------------------------------------------------------------- R6

const HTTP_WITH_ENCODING: &str = "pub enum Encoding { Json, Raw }\n\
    fn decode(e: Encoding) { match e { Encoding::Json => a(), Encoding::Raw => b() } }";

#[test]
fn r6_fires_on_encoding_missing_from_the_client_side() {
    // http.rs declares and decodes both variants; loadgen only ever
    // encodes Json — the Raw half of the wire contract is unwired.
    let loadgen = "fn enc() { let b = Encoding::Json; use_it(b); }";
    let rules = rules_for(&[
        ("coordinator/http.rs", HTTP_WITH_ENCODING),
        ("coordinator/loadgen.rs", loadgen),
    ]);
    assert_eq!(rules, vec!["R6"]);
}

#[test]
fn r6_silent_when_both_sides_handle_every_encoding() {
    let loadgen =
        "fn enc(e: Encoding) { match e { Encoding::Json => a(), Encoding::Raw => b() } }";
    let rules = rules_for(&[
        ("coordinator/http.rs", HTTP_WITH_ENCODING),
        ("coordinator/loadgen.rs", loadgen),
    ]);
    assert!(rules.is_empty(), "{rules:?}");
}

// ---------------------------------------------------------------- R7

const STORE_WITH_ENUM: &str = "pub enum ArtifactError { \
     DigestMismatch { expected: Digest, actual: Digest }, \
     MissingBlob { blob: String }, \
     Io { source: E } }";

#[test]
fn r7_fires_on_artifact_error_missing_from_cli_rendering() {
    // http.rs maps every variant; main.rs hides Io behind a wildcard —
    // an artifact io failure would surface with no actionable hint.
    let main = "fn hint(e: &ArtifactError) -> &str { match e { ArtifactError::DigestMismatch { .. } => a(), ArtifactError::MissingBlob { .. } => b(), _ => c() } }";
    let http = "fn status(e: &ArtifactError) -> u16 { match e { ArtifactError::DigestMismatch { .. } => 500, ArtifactError::MissingBlob { .. } => 404, ArtifactError::Io { .. } => 500 } }";
    let rules = rules_for(&[
        ("artifact/store.rs", STORE_WITH_ENUM),
        ("main.rs", main),
        ("coordinator/http.rs", http),
    ]);
    assert_eq!(rules, vec!["R7"]);
}

#[test]
fn r7_silent_when_both_consumers_map_every_variant() {
    let both = "fn m(e: &ArtifactError) { match e { ArtifactError::DigestMismatch { .. } => a(), ArtifactError::MissingBlob { .. } => b(), ArtifactError::Io { .. } => c() } }";
    let rules = rules_for(&[
        ("artifact/store.rs", STORE_WITH_ENUM),
        ("main.rs", both),
        ("coordinator/http.rs", both),
    ]);
    assert!(rules.is_empty(), "{rules:?}");
}

// ---------------------------------------------------------------- R4

#[test]
fn r4_fires_on_counter_missing_from_an_emitter() {
    let bad = "pub struct Metrics { pub requests_in: AtomicU64, pub requests_done: AtomicU64 }\n\
               impl Metrics {\n\
                 pub fn report(&self) -> String { format!(\"in={}\", Self::get(&self.requests_in)) }\n\
                 pub fn to_json(&self) -> Json { Json::obj(vec![(\"requests_in\", num(&self.requests_in)), (\"requests_done\", num(&self.requests_done))]) }\n\
               }";
    // requests_done surfaces in to_json but not report().
    assert_eq!(rules_for(&[("coordinator/metrics.rs", bad)]), vec!["R4"]);
}

#[test]
fn r4_accepts_string_key_and_name_helper_emission() {
    let good = "pub struct Metrics { pub breaker_state: AtomicU64 }\n\
                impl Metrics {\n\
                  pub fn report(&self) -> String { self.breaker_state_name().to_string() }\n\
                  pub fn to_json(&self) -> Json { Json::obj(vec![(\"breaker_state\", Json::Null)]) }\n\
                }";
    let rules = rules_for(&[("coordinator/metrics.rs", good)]);
    assert!(rules.is_empty(), "{rules:?}");
}

// ---------------------------------------------------------------- R5

#[test]
fn r5_fires_on_guard_held_across_blocking_call() {
    let bad = "fn f(&self) { let st = self.state.lock().unwrap(); self.backend.run_batch(&st.x, 4); }";
    assert_eq!(rules_for(&[("coordinator/pool.rs", bad)]), vec!["R1", "R5"]);
}

#[test]
fn r5_silent_when_guard_is_dropped_first() {
    let good = "fn f(&self) { let st = self.state.plock(); let x = st.x.clone(); drop(st); self.backend.run_batch(&x, 4); }";
    let rules = rules_for(&[("coordinator/pool.rs", good)]);
    assert!(rules.is_empty(), "{rules:?}");
}

// ---------------------------------------------------------------- pragmas

#[test]
fn pragma_with_reason_suppresses() {
    let src = "fn f() {\n  // analyze:allow(the invariant holds by construction)\n  x.unwrap();\n}";
    assert!(rules_for(&[("coordinator/server.rs", src)]).is_empty());
}

#[test]
fn pragma_without_reason_is_a_finding_and_does_not_suppress() {
    let src = "fn f() {\n  // analyze:allow()\n  x.unwrap();\n}";
    let rules = rules_for(&[("coordinator/server.rs", src)]);
    assert_eq!(rules, vec!["P0", "R1"], "a reasonless pragma must not buy suppression");
}

// ---------------------------------------------------------------- reports

#[test]
fn json_report_carries_findings() {
    let p = Project::from_memory(&[("coordinator/server.rs", "fn f() { x.unwrap(); }")]);
    let findings = analyze(&p);
    let j = report_json(&p, &findings);
    assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
    let rows = j.get("findings").and_then(Json::as_arr).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].get("rule").and_then(Json::as_str), Some("R1"));
    assert_eq!(rows[0].get("line").and_then(Json::as_usize), Some(1));
}

// ---------------------------------------------------------------- the real tree

#[test]
fn shipped_source_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let project = Project::load(&src).expect("crate source loads");
    assert!(project.files.len() > 20, "walk found only {} files", project.files.len());
    let findings = analyze(&project);
    assert!(findings.is_empty(), "\n{}", render_text(&project, &findings));
}

// ---------------------------------------------------------------- runtime twin

#[test]
fn metrics_audit_catches_imbalanced_ledger() {
    let m = Metrics::default();
    for _ in 0..5 {
        Metrics::inc(&m.requests_in);
    }
    for _ in 0..3 {
        Metrics::inc(&m.requests_done);
    }
    // Two admissions never reached an outcome class: dropped on the floor.
    let err = m.audit().expect_err("imbalanced ledger must be rejected");
    assert!(err.contains("requests_in=5"), "{err}");
    Metrics::inc(&m.requests_shed);
    Metrics::inc(&m.requests_failed);
    assert_eq!(m.audit(), Ok(()));
}
