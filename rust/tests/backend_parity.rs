//! Backend parity through the unified API — artifact-free, feature-free.
//!
//! Builds synthetic TinyResNet fixtures (`backend::synth`) and checks that
//! the `QgemmBackend` and `FloatRefBackend` resolved through
//! `backend::registry()` agree: close logits under all-Fixed-8 masks,
//! argmax agreement on confidently-separated samples, and bit-exact
//! determinism across the cached pack. Runs under `--no-default-features`
//! (no PJRT, no `make artifacts`).

use ilmpq::backend::{self, synth, BackendInit, InferenceBackend};
use ilmpq::quant::{Provenance, QuantPlan, Ratio, Scheme};
use ilmpq::util::Rng;

const H: usize = 8;
const W: usize = 8;
const C: usize = 3;
const CLASSES: usize = 5;

fn fixture(seed: u64) -> (BackendInit, Rng) {
    let mut rng = Rng::new(seed);
    let m = synth::tiny_manifest(H, W, C, &[4, 8], CLASSES);
    let params = synth::random_params(&m, &mut rng);
    let init = BackendInit::new(m, params);
    (init, rng)
}

#[test]
fn fixed8_qgemm_tracks_float_through_registry() {
    // With every row at 8 bits the packed path only adds ~1/254 relative
    // weight + activation noise per layer: logits must stay close to the
    // float backend, and argmax must agree wherever the float margin is
    // clear.
    let (mut init, mut rng) = fixture(5);
    init.plan = Some(QuantPlan::from_mask_set(
        synth::uniform_masks(&init.manifest, Scheme::Fixed8),
        Provenance::Uniform { scheme: Scheme::Fixed8.label().into() },
    ));
    let qgemm = backend::create("qgemm", &init).unwrap();
    // Float reference on the same raw params (frozen=false: the Fixed-8
    // freeze would *itself* be the quantization noise under test).
    init.frozen = false;
    let float = backend::create("float", &init).unwrap();

    let b = 16usize;
    let x: Vec<f32> = (0..b * H * W * C).map(|_| rng.normal()).collect();
    let lq = qgemm.run_batch(&x, b).unwrap();
    let lf = float.run_batch(&x, b).unwrap();
    assert_eq!(lq.logits.len(), b * CLASSES);
    assert_eq!(lf.logits.len(), b * CLASSES);

    let scale = lf.logits.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-3);
    for (a, c) in lq.logits.iter().zip(&lf.logits) {
        assert!(
            (a - c).abs() < 0.05 * scale + 0.05,
            "packed {a} vs float {c} (scale {scale})"
        );
    }
    // Argmax agreement wherever the float top-1 margin exceeds twice the
    // per-logit noise bound asserted above — at that margin a flip is
    // arithmetically impossible, so this check can never be flaky.
    for i in 0..b {
        let row = &lf.logits[i * CLASSES..(i + 1) * CLASSES];
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(|a, c| c.partial_cmp(a).unwrap());
        let margin = sorted[0] - sorted[1];
        if margin > 2.0 * (0.05 * scale + 0.05) {
            assert_eq!(
                lq.preds[i], lf.preds[i],
                "sample {i}: argmax diverged with clear margin {margin}"
            );
        }
    }
}

#[test]
fn qgemm_prepare_caches_and_stays_bit_exact() {
    let (mut init, mut rng) = fixture(9);
    init.plan = Some(QuantPlan::from_mask_set(
        synth::random_masks(&init.manifest, Ratio::new(65.0, 30.0, 5.0), &mut rng),
        Provenance::Synthetic { seed: 9, ratio: "65:30:5".into() },
    ));
    init.threads = Some(3);
    let be = backend::create("qgemm", &init).unwrap();
    be.prepare().unwrap();
    let x: Vec<f32> = (0..2 * H * W * C).map(|_| rng.normal()).collect();
    let a = be.run_batch(&x, 2).unwrap();
    let b = be.run_batch(&x, 2).unwrap();
    assert!(a
        .logits
        .iter()
        .zip(&b.logits)
        .all(|(x1, x2)| x1.to_bits() == x2.to_bits()));
    assert_eq!(a.preds, b.preds);
    // A second instance over the same inputs packs to the same codes.
    let be2 = backend::create("qgemm", &init).unwrap();
    let c = be2.run_batch(&x, 2).unwrap();
    assert!(a
        .logits
        .iter()
        .zip(&c.logits)
        .all(|(x1, x2)| x1.to_bits() == x2.to_bits()));
}

#[test]
fn per_batch_timing_is_reported() {
    let (mut init, mut rng) = fixture(13);
    init.plan = Some(QuantPlan::from_mask_set(
        synth::random_masks(&init.manifest, Ratio::new(65.0, 30.0, 5.0), &mut rng),
        Provenance::Synthetic { seed: 13, ratio: "65:30:5".into() },
    ));
    let be = backend::create("qgemm", &init).unwrap();
    let x: Vec<f32> = (0..4 * H * W * C).map(|_| rng.normal()).collect();
    let out = be.run_batch(&x, 4).unwrap();
    assert!(out.elapsed > std::time::Duration::ZERO);
    assert_eq!(out.classes, CLASSES);
}

#[test]
fn registry_is_the_single_source_of_backend_names() {
    // Unknown names list the registry; CPU backends are always available.
    let (init, _) = fixture(1);
    let err = backend::create("does-not-exist", &init).unwrap_err();
    let msg = format!("{err:#}");
    for name in ["pjrt", "qgemm", "float"] {
        assert!(msg.contains(name), "error must list {name}: {msg}");
    }
    let names = backend::available_names();
    assert!(names.contains(&"qgemm") && names.contains(&"float"));
    // `spec` rejects unknown names the same way (main.rs validates early).
    assert!(backend::spec("hls").is_err());
    assert!(backend::spec("qgemm").is_ok());
}

#[test]
fn pjrt_selection_fails_cleanly_without_engine() {
    // Whatever the build mode, asking for pjrt with no loaded runtime must
    // be a clear registry-level error, not a panic or a silent default.
    let (mut init, mut rng) = fixture(3);
    init.plan = Some(QuantPlan::from_mask_set(
        synth::random_masks(&init.manifest, Ratio::new(65.0, 30.0, 5.0), &mut rng),
        Provenance::Synthetic { seed: 3, ratio: "65:30:5".into() },
    ));
    let err = backend::create("pjrt", &init).unwrap_err();
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
}
