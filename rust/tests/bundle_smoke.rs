//! End-to-end smoke tests for the content-addressed artifact path
//! (`artifact::*` + `coordinator::pool::pack_pool`/`from_bundle` + the
//! digest-reporting HTTP routes), on the artifact-free synthetic fixtures.
//!
//! Pinned here (the acceptance contract for `ilmpq bundle` + `serve
//! --bundle`):
//!
//! * pack → wipe the source → boot from the store by digest → logits are
//!   **bit-identical** to the pool the bundle was packed from;
//! * the serving surface reports what executes: `/v1/models` and
//!   `/v1/models/{name}/healthz` carry the lockfile's blob digests and the
//!   plan content digest, and `/v1/models/{name}/verify` re-checks the
//!   store live (404 `no_bundle` for entries not booted from a bundle);
//! * one flipped byte in a stored blob fails the boot loudly with a
//!   `DigestMismatch` naming the blob — never a silent fallback;
//! * a tampered lockfile is rejected: unknown keys at parse time, a
//!   flipped-but-well-formed digest as `MissingBlob` at verify time.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use ilmpq::artifact::{ArtifactError, Bundle, Digest, Store};
use ilmpq::coordinator::pool::{pack_pool, ServerPool};
use ilmpq::coordinator::{HttpClient, HttpConfig, HttpServer, HttpTarget};
use ilmpq::util::{Json, Rng};

fn start_pool_front(pool: ServerPool) -> HttpServer {
    HttpServer::start_pool(
        Arc::new(pool),
        HttpConfig { addr: "127.0.0.1:0".into(), workers: 8, ..Default::default() },
    )
    .unwrap()
}

fn client_for(front: &HttpServer) -> HttpClient {
    let target = HttpTarget::parse(&format!("http://{}", front.local_addr())).unwrap();
    HttpClient::connect(&target, Duration::from_secs(30))
}

fn infer_body(image: &[f32]) -> String {
    Json::obj(vec![(
        "image",
        Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
    )])
    .to_string_compact()
}

fn wire_logits(body: &str) -> Vec<f32> {
    Json::parse(body)
        .unwrap()
        .get("logits")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("no logits in {body}"))
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

/// A fresh scratch directory per test (the store must start empty).
fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("ilmpq-bundle-smoke-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The headline round trip: pack the synthetic pair, throw the packing
/// pool away, boot a fresh pool purely from the store by digest, and the
/// logits come back bit-for-bit. Along the way: every digest-reporting
/// surface must agree with the lockfile.
#[test]
fn pack_then_serve_from_store_is_bit_identical() {
    const SEED: u64 = 11;
    let image: Vec<f32>;
    let reference_logits;

    // Reference: serve the pool the ordinary way (`serve --pool synth`).
    {
        let front = start_pool_front(ServerPool::synthetic_pair(SEED).unwrap());
        let mut client = client_for(&front);
        let (code, body) = client.request("GET", "/v1/models/tiny/healthz", None).unwrap();
        assert_eq!(code, 200, "{body}");
        let h = Json::parse(&body).unwrap();
        let image_elems = h.get("image_elems").and_then(Json::as_usize).unwrap();
        // Ordinary entries are not bundle-backed: no digests to verify.
        assert_eq!(h.get("bundle"), Some(&Json::Null), "{body}");
        let (code, body) = client.request("GET", "/v1/models/tiny/verify", None).unwrap();
        assert_eq!(code, 404, "{body}");
        assert_eq!(
            Json::parse(&body).unwrap().get("kind").and_then(Json::as_str),
            Some("no_bundle"),
            "{body}"
        );
        image = {
            let mut img = vec![0f32; image_elems];
            Rng::new(9).fill_normal(&mut img, 1.0);
            img
        };
        let (code, body) =
            client.request("POST", "/v1/models/tiny/infer", Some(&infer_body(&image))).unwrap();
        assert_eq!(code, 200, "{body}");
        reference_logits = wire_logits(&body);
        front.stop();
    }

    // Pack into a fresh store, round-trip the lockfile through disk, and
    // drop the packing pool — the store + lockfile are now the only source.
    let dir = temp_dir("roundtrip");
    let store = Store::open(&dir.join("store")).unwrap();
    let lock_path = dir.join("ilmpq.lock.json");
    {
        let packing = ServerPool::synthetic_pair(SEED).unwrap();
        let bundle = pack_pool(&packing, &store).unwrap();
        bundle.save(&lock_path).unwrap();
    }
    let bundle = Bundle::load(&lock_path).unwrap();
    assert_eq!(bundle.default, "tiny");
    assert_eq!(bundle.models.len(), 2);

    // Boot purely from the store (`serve --bundle`): every byte re-hashed.
    let front = start_pool_front(ServerPool::from_bundle(&bundle, &store).unwrap());
    let mut client = client_for(&front);

    let (code, body) =
        client.request("POST", "/v1/models/tiny/infer", Some(&infer_body(&image))).unwrap();
    assert_eq!(code, 200, "{body}");
    assert_eq!(
        wire_logits(&body),
        reference_logits,
        "bundle-booted logits drifted from the packing pool"
    );

    // `/v1/models` reports the executing digests, and they are exactly the
    // lockfile's.
    let (code, body) = client.request("GET", "/v1/models", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let listing = Json::parse(&body).unwrap();
    for row in listing.get("models").and_then(Json::as_arr).unwrap() {
        let name = row.get("name").and_then(Json::as_str).unwrap();
        let bm = bundle.model(name).unwrap_or_else(|| panic!("extra model {name}"));
        let b = row.get("bundle").expect("bundle digests in the listing");
        for (key, digest) in
            [("manifest", &bm.manifest), ("params", &bm.params), ("plan", &bm.plan)]
        {
            assert_eq!(
                b.get(key).and_then(Json::as_str),
                Some(digest.to_hex().as_str()),
                "{name}/{key} digest drifted from the lockfile: {body}"
            );
        }
    }

    // healthz carries both digest views: the lockfile blobs and the
    // identity-blind plan content digest.
    let (code, body) = client.request("GET", "/v1/models/tiny/healthz", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let h = Json::parse(&body).unwrap();
    let pd = h.get("plan_digest").and_then(Json::as_str).unwrap();
    assert!(Digest::parse(pd).is_ok(), "plan_digest is not a digest: {body}");
    let tiny = bundle.model("tiny").unwrap();
    assert_eq!(
        h.get("bundle").and_then(|b| b.get("params")).and_then(Json::as_str),
        Some(tiny.params.to_hex().as_str()),
        "{body}"
    );

    // The live verify route re-hashes all three blobs against the store.
    let (code, body) = client.request("GET", "/v1/models/tiny/verify", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("verified"), Some(&Json::Bool(true)), "{body}");
    assert_eq!(v.get("blobs").and_then(Json::as_usize), Some(3), "{body}");
    assert_eq!(v.get("plan_matches_bundle"), Some(&Json::Bool(true)), "{body}");

    front.stop();
}

/// One flipped byte in a stored blob: the boot must die loudly with a
/// `DigestMismatch` naming the blob, and `Store::verify` must report the
/// expected and actual digests. Restore the byte and everything heals.
#[test]
fn flipped_blob_byte_fails_boot_and_verify_loudly() {
    let dir = temp_dir("tamper");
    let store = Store::open(&dir.join("store")).unwrap();
    let bundle = pack_pool(&ServerPool::synthetic_pair(13).unwrap(), &store).unwrap();
    let tiny = bundle.model("tiny").unwrap();

    let path = store.path_of(&tiny.params);
    let clean = std::fs::read(&path).unwrap();
    let mut dirty = clean.clone();
    dirty[0] ^= 0x01;
    std::fs::write(&path, &dirty).unwrap();

    let err = ServerPool::from_bundle(&bundle, &store)
        .err()
        .expect("boot from a tampered store must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("mismatch"), "boot error does not name the mismatch: {msg}");
    assert!(msg.contains("tiny/params"), "boot error does not name the blob: {msg}");

    match store.verify(&tiny.params, "tiny/params") {
        Err(ArtifactError::DigestMismatch { blob, expected, actual }) => {
            assert_eq!(blob, "tiny/params");
            assert_eq!(expected, tiny.params);
            assert_ne!(actual, expected);
        }
        other => panic!("expected DigestMismatch, got {other:?}"),
    }

    std::fs::write(&path, &clean).unwrap();
    store.verify(&tiny.params, "tiny/params").unwrap();
    ServerPool::from_bundle(&bundle, &store).unwrap();
}

/// Lockfile tampering: unknown keys are rejected at parse time (strict
/// schema, like FaultSpec), and a digest edited to another well-formed
/// value fails as `MissingBlob` — the store simply does not hold it.
#[test]
fn tampered_lockfile_is_rejected() {
    let dir = temp_dir("lockfile");
    let store = Store::open(&dir.join("store")).unwrap();
    let bundle = pack_pool(&ServerPool::synthetic_pair(17).unwrap(), &store).unwrap();

    // Unknown top-level key.
    let Json::Obj(mut map) = bundle.to_json() else { panic!("lockfile is an object") };
    map.insert("mirror_url".to_string(), Json::Str("http://x".into()));
    let err = Bundle::from_json(&Json::Obj(map)).unwrap_err();
    assert!(format!("{err:#}").contains("unknown"), "{err:#}");

    // A flipped-but-well-formed digest: nothing in the store has that
    // address, so the failure mode is a missing blob, named.
    let mut edited = bundle.clone();
    edited.models[0].params = Digest::of(b"not the params");
    let name = edited.models[0].name.clone();
    let err = ServerPool::from_bundle(&edited, &store)
        .err()
        .expect("an edited digest must not boot");
    let msg = format!("{err:#}");
    assert!(msg.contains("missing blob"), "{msg}");
    assert!(msg.contains(&format!("{name}/params")), "{msg}");
}
