//! Chaos smoke: the self-healing serving loop under a seeded mixed fault
//! schedule, artifact-free on the synthetic qgemm fixture (runs in the
//! `--no-default-features` CI leg).
//!
//! Pinned here (the acceptance contract for supervised execution):
//!
//! * **answer-exactly-once under chaos** — with panics, stalls past the
//!   watchdog deadline, garbage logits, injected errors, and a leading
//!   failure burst all firing, every offered request gets exactly one typed
//!   reply: outcome classes sum to `requests`, `lost == 0`;
//! * **no slot leaks** — a follow-up round at the same `queue_depth` still
//!   admits after a chaos round (abandoned watchdog executions and panics
//!   released their slots);
//! * **poison quarantine** — re-splitting a failed batch into singletons
//!   isolates exactly the poison request; its batch-mates are answered with
//!   logits bit-identical to a clean backend's;
//! * **breaker transitions** — closed → open (shedding `Unavailable`) →
//!   half-open probe → closed, visible in `Metrics::to_json()` and in
//!   `/v1/healthz` ready-vs-live (503 while not ready, back to 200);
//! * **degraded serving** — with a fallback backend, an open breaker keeps
//!   serving instead of shedding.

use std::sync::Arc;
use std::time::Duration;

use ilmpq::backend::{FaultSpec, FaultyBackend, InferenceBackend, POISON_MAGIC};
use ilmpq::coordinator::{
    loadgen, HttpClient, HttpConfig, HttpServer, HttpTarget, Metrics, ServeConfig,
    ServeError, Server,
};
use ilmpq::runtime::Manifest;
use ilmpq::util::{Json, Rng};

/// Fixture bundle: manifest, fault-wrapped backend, healthy inner backend
/// (for bit-equal reference computations), and a plan-carrying config.
type Fixture = (Manifest, Arc<dyn InferenceBackend>, Arc<dyn InferenceBackend>, ServeConfig);

/// Synthetic fixture wrapped in fault injection; also returns the healthy
/// inner backend for reference computations.
fn chaos_fixture(plan_name: &str, spec: FaultSpec, seed: u64) -> Fixture {
    let (m, inner, plan) = loadgen::synth_fixture("qgemm", plan_name, Some(1), seed).unwrap();
    let faulty: Arc<dyn InferenceBackend> =
        Arc::new(FaultyBackend::new(inner.clone(), spec));
    let cfg = ServeConfig { plan: Some(plan), ..Default::default() };
    (m, faulty, inner, cfg)
}

fn normal_image(img: usize, rng: &mut Rng) -> Vec<f32> {
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    image
}

#[test]
fn chaos_run_answers_every_request_exactly_once() {
    // The full mixed schedule: 10% each of panic / stall-past-deadline /
    // error / garbage, plus a leading 5-batch failure burst — against the
    // whole supervision stack (watchdog + retry + breaker + no fallback).
    let (m, faulty, _inner, cfg) = chaos_fixture("chs", FaultSpec::chaos(101), 47);
    let server = Server::start(
        &m,
        faulty,
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            execute_deadline: Some(Duration::from_millis(100)),
            retries: 1,
            retry_backoff: Duration::from_millis(2),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(50),
            ..cfg
        },
    )
    .unwrap();
    let spec = loadgen::LoadSpec {
        requests: 160,
        rate: 0.0, // unpaced: maximal batch-assembly pressure
        malformed_frac: 0.1,
        poison_frac: 0.05,
        scenario: loadgen::Scenario::Chaos,
        seed: 103,
    };
    let (r, metrics) = loadgen::run(server, &m, &spec);
    assert_eq!(r.lost, 0, "no reply channel may be dropped under chaos: {r:?}");
    assert_eq!(r.slow, 0, "chaos run must drain inside the deadline: {r:?}");
    assert_eq!(
        r.done + r.invalid + r.shed + r.failed + r.shutdown + r.timeout + r.unavailable,
        r.requests,
        "outcome classes must sum to requests: {r:?}"
    );
    assert!(r.done > 0, "chaos must not starve every request: {r:?}");
    assert!(r.invalid > 0, "malformed fraction must surface: {r:?}");
    // The server-side ledger agrees: everything admitted was answered.
    let answered = Metrics::get(&metrics.requests_done)
        + Metrics::get(&metrics.requests_invalid)
        + Metrics::get(&metrics.requests_shed)
        + Metrics::get(&metrics.requests_failed)
        + Metrics::get(&metrics.requests_shutdown)
        + Metrics::get(&metrics.requests_timeout)
        + Metrics::get(&metrics.requests_unavailable)
        + Metrics::get(&metrics.requests_quarantined);
    assert_eq!(answered, Metrics::get(&metrics.requests_in), "metrics sum invariant");
}

#[test]
fn chaos_round_leaks_no_queue_slots() {
    // Two sequential rounds at a tiny queue_depth: if any fault path leaked
    // its admission slot (abandoned stall, contained panic, quarantine),
    // round two would shed QueueFull at an empty server.
    let spec = FaultSpec {
        seed: 11,
        panic_prob: 0.3,
        error_prob: 0.3,
        stall_prob: 0.2,
        stall_ms: 1_000,
        garbage_prob: 0.2,
        ..FaultSpec::default()
    };
    let (m, faulty, _inner, cfg) = chaos_fixture("chl", spec, 53);
    let depth = 8;
    let server = Server::start(
        &m,
        faulty,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: depth,
            execute_deadline: Some(Duration::from_millis(50)),
            retries: 1,
            retry_backoff: Duration::from_millis(1),
            ..cfg
        },
    )
    .unwrap();
    let img = m.data.image_elems();
    let mut rng = Rng::new(13);
    for round in 0..2 {
        // Collect-before-next-round: in_system must be back to 0, so a
        // full depth's worth of requests is admissible again.
        let pending: Vec<_> = (0..depth)
            .map(|_| server.submit(normal_image(img, &mut rng)))
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let reply = rx
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("round {round} request {i} unanswered: {e}"));
            assert!(
                !matches!(reply, Err(ServeError::QueueFull { .. })),
                "round {round} request {i} shed at an un-leaked depth {depth}"
            );
        }
    }
    server.stop();
}

#[test]
fn quarantine_isolates_the_poison_request_with_bit_correct_neighbors() {
    // Default FaultSpec: no random faults, poison detection on — the only
    // failures come from the poison sentinel.
    let (m, faulty, inner, cfg) = chaos_fixture("chq", FaultSpec::default(), 59);
    let server = Server::start(
        &m,
        faulty,
        ServeConfig {
            workers: 1,
            // Generous batching window so all four requests assemble into
            // one exec_size-4 batch even on a hiccuping CI runner (a full
            // batch assembles immediately, so this costs no latency).
            max_wait: Duration::from_secs(1),
            retries: 1,
            retry_backoff: Duration::from_millis(1),
            ..cfg
        },
    )
    .unwrap();
    let img = m.data.image_elems();
    let mut rng = Rng::new(17);
    let mut images: Vec<Vec<f32>> = (0..4).map(|_| normal_image(img, &mut rng)).collect();
    images[2][0] = POISON_MAGIC;
    let pending: Vec<_> =
        images.iter().map(|im| server.submit(im.clone())).collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        if i == 2 {
            // Exactly the poison request fails, and it fails *after*
            // isolation (quarantined), not as collateral batch damage.
            let err = reply.expect_err("poison request must not be served");
            assert!(
                matches!(&err, ServeError::BackendFailed(msg) if msg.contains("poison")),
                "{err:?}"
            );
        } else {
            // Batch-mates recover via singleton retry with logits
            // bit-identical to a clean singleton run on the inner backend.
            let resp = reply.unwrap_or_else(|e| panic!("neighbor {i} lost to poison: {e:?}"));
            let reference = inner.run_batch(&images[i], 1).unwrap();
            assert_eq!(resp.logits, reference.logits, "neighbor {i} logits drifted");
            assert_eq!(resp.pred, reference.preds[0]);
        }
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_quarantined), 1);
    assert_eq!(Metrics::get(&metrics.requests_recovered), 3);
    assert_eq!(Metrics::get(&metrics.requests_done), 3);
}

#[test]
fn breaker_opens_sheds_probes_and_recloses() {
    // A leading 3-batch burst opens the breaker (threshold 3); the healthy
    // tail lets the half-open probe succeed and re-close it.
    let spec = FaultSpec {
        seed: 19,
        burst_period: u64::MAX,
        burst_len: 3,
        ..FaultSpec::default()
    };
    let (m, faulty, _inner, cfg) = chaos_fixture("chb", spec, 61);
    let server = Server::start(
        &m,
        faulty,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            breaker_threshold: 3,
            // Wide enough that the shed assertion below cannot race the
            // cooldown expiring on a slow CI runner.
            breaker_cooldown: Duration::from_secs(2),
            ..cfg
        },
    )
    .unwrap();
    let img = m.data.image_elems();
    let mut rng = Rng::new(23);
    assert!(server.is_ready());
    assert_eq!(server.breaker_state(), "closed");
    // Three consecutive burst failures → open.
    for _ in 0..3 {
        let reply = server
            .submit(normal_image(img, &mut rng))
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert!(matches!(reply, Err(ServeError::BackendFailed(_))), "{reply:?}");
    }
    assert_eq!(server.breaker_state(), "open");
    assert!(!server.is_ready(), "open breaker must report not-ready");
    // While cooling down, admission sheds immediately with Unavailable.
    let reply = server
        .submit(normal_image(img, &mut rng))
        .recv_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(matches!(reply, Err(ServeError::Unavailable)), "{reply:?}");
    // After the cooldown, the next batch is the half-open probe; the burst
    // is over, so it succeeds and the breaker re-closes.
    std::thread::sleep(Duration::from_millis(2_200));
    let reply = server
        .submit(normal_image(img, &mut rng))
        .recv_timeout(Duration::from_secs(30))
        .unwrap();
    assert!(reply.is_ok(), "probe traffic must be served: {reply:?}");
    assert_eq!(server.breaker_state(), "closed");
    assert!(server.is_ready());
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    // The transition ledger made it into the serialized metrics.
    let j = metrics.to_json();
    assert_eq!(j.get("breaker_state").and_then(Json::as_str), Some("closed"));
    assert!(j.get("breaker_opened").and_then(Json::as_f64).unwrap() >= 1.0, "{j:?}");
    assert!(j.get("breaker_half_open").and_then(Json::as_f64).unwrap() >= 1.0, "{j:?}");
    assert!(j.get("breaker_closed").and_then(Json::as_f64).unwrap() >= 1.0, "{j:?}");
    assert!(
        j.get("requests_unavailable").and_then(Json::as_f64).unwrap() >= 1.0,
        "{j:?}"
    );
}

#[test]
fn open_breaker_serves_degraded_on_the_fallback_backend() {
    // Primary fails every batch; the float fallback (same fixture seed →
    // same weights) keeps serving while the breaker is open.
    let spec = FaultSpec { seed: 29, error_prob: 1.0, ..FaultSpec::default() };
    let (m, faulty, _inner, cfg) = chaos_fixture("chf", spec, 67);
    let (_m2, fallback, _plan2) = loadgen::synth_fixture("float", "chf", Some(1), 67).unwrap();
    let server = Server::start_with_fallback(
        &m,
        faulty,
        Some(fallback),
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            breaker_threshold: 2,
            // Long cooldown: once open, the rest of the test runs degraded
            // (no probe can fire).
            breaker_cooldown: Duration::from_secs(30),
            ..cfg
        },
    )
    .unwrap();
    assert!(!server.is_degraded(), "healthy start");
    let img = m.data.image_elems();
    let mut rng = Rng::new(31);
    let mut done = 0usize;
    for _ in 0..8 {
        if server
            .submit(normal_image(img, &mut rng))
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .is_ok()
        {
            done += 1;
        }
    }
    // The first `threshold` batches fail on the primary; everything after
    // the breaker opens is served by the fallback.
    assert!(done >= 5, "degraded mode must keep serving: {done}/8");
    assert!(server.is_degraded(), "open breaker + fallback = degraded");
    assert_eq!(server.breaker_state(), "open");
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert!(Metrics::get(&metrics.fallback_batches) >= 5);
    assert_eq!(Metrics::get(&metrics.requests_unavailable), 0, "fallback never sheds");
}

#[test]
fn healthz_tracks_breaker_readiness_over_http() {
    // Same open→probe→closed arc as above, observed through the HTTP front
    // end: /v1/healthz answers 503 + ready=false while the breaker is not
    // closed (liveness intact), then recovers to 200 + ready=true.
    let spec = FaultSpec {
        seed: 37,
        burst_period: u64::MAX,
        burst_len: 2,
        ..FaultSpec::default()
    };
    let (m, faulty, _inner, cfg) = chaos_fixture("chz", spec, 71);
    let server = Server::start(
        &m,
        faulty,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            breaker_threshold: 2,
            // Wide enough that the 503-while-open assertions below cannot
            // race the cooldown expiring on a slow CI runner.
            breaker_cooldown: Duration::from_secs(2),
            ..cfg
        },
    )
    .unwrap();
    let front = HttpServer::start(
        server,
        &m,
        HttpConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() },
    )
    .unwrap();
    let target = HttpTarget::parse(&format!("http://{}", front.local_addr())).unwrap();
    let mut client = HttpClient::connect(&target, Duration::from_secs(30));
    let img = m.data.image_elems();
    let mut rng = Rng::new(41);
    let body = |image: &[f32]| {
        Json::obj(vec![(
            "image",
            Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
        )])
        .to_string_compact()
    };

    // Healthy: ready.
    let (code, hbody) = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200, "{hbody}");

    // Two burst failures open the breaker.
    for _ in 0..2 {
        let (code, b) = client
            .request("POST", "/v1/infer", Some(&body(&normal_image(img, &mut rng))))
            .unwrap();
        assert_eq!(code, 500, "{b}");
    }
    let (code, hbody) = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 503, "open breaker must 503 healthz: {hbody}");
    let h = Json::parse(&hbody).unwrap();
    assert_eq!(h.get("live"), Some(&Json::Bool(true)), "{hbody}");
    assert_eq!(h.get("ready"), Some(&Json::Bool(false)), "{hbody}");
    assert_eq!(h.get("breaker").and_then(Json::as_str), Some("open"), "{hbody}");

    // Cooldown elapses; the probe succeeds and readiness returns.
    std::thread::sleep(Duration::from_millis(2_200));
    let (code, b) = client
        .request("POST", "/v1/infer", Some(&body(&normal_image(img, &mut rng))))
        .unwrap();
    assert_eq!(code, 200, "probe must serve: {b}");
    let (code, hbody) = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200, "recovered breaker must 200 healthz: {hbody}");
    let h = Json::parse(&hbody).unwrap();
    assert_eq!(h.get("ready"), Some(&Json::Bool(true)), "{hbody}");
    assert_eq!(h.get("breaker").and_then(Json::as_str), Some("closed"), "{hbody}");
    front.stop();
}
