//! Shared gate for the artifact-dependent integration suites.
//!
//! `make artifacts` and the `pjrt` cargo feature are environment
//! prerequisites, not invariants under test: when either is missing, the
//! suites skip with a note so the pure-CPU test run stays green everywhere
//! (CI builds with `--no-default-features` and ships no artifacts). Any
//! *other* load failure — corrupt manifest, PJRT client init error — is a
//! real regression and still fails loudly.

// Each integration test crate compiles this module separately and uses only
// the helpers it needs.
#![allow(dead_code)]

use ilmpq::runtime::{Manifest, Runtime};

/// True when the error is an absent environment (no artifacts dir, or a
/// build without the `pjrt` feature) rather than a regression.
fn is_missing_environment(e: &anyhow::Error) -> bool {
    !Manifest::default_dir().join("manifest.json").exists()
        || format!("{e:#}").contains("without the `pjrt` feature")
}

pub fn runtime_or_skip(suite: &str) -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) if is_missing_environment(&e) => {
            eprintln!("SKIP {suite} (no artifacts / no pjrt): {e:#}");
            None
        }
        Err(e) => panic!("{suite}: runtime failed to load with artifacts present: {e:#}"),
    }
}

pub fn manifest_or_skip(suite: &str) -> Option<Manifest> {
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) if is_missing_environment(&e) => {
            eprintln!("SKIP {suite} (no artifacts): {e:#}");
            None
        }
        Err(e) => panic!("{suite}: manifest failed to load with artifacts present: {e:#}"),
    }
}
