//! End-to-end integration over the PJRT runtime: every AOT artifact loads,
//! compiles, and executes with correct semantics from Rust. Requires
//! `make artifacts` and the `pjrt` feature; when either is missing the
//! tests skip with a note (like `qgemm_integration.rs`) so the pure-CPU
//! suite stays runnable everywhere. These tests ARE the paper's pipeline
//! in miniature: assignment → QAT steps → evaluation → batched serving.

use std::sync::Arc;
use std::time::Duration;

use ilmpq::coordinator::sensitivity::{filter_eigs, top_k_overlap};
use ilmpq::coordinator::trainer::Trainer;
use ilmpq::coordinator::{ServeConfig, Server};
use ilmpq::runtime::{HostTensor, Runtime};

mod common;

fn runtime_or_skip() -> Option<Runtime> {
    common::runtime_or_skip("e2e runtime")
}

#[test]
fn infer_all_batch_sizes_execute() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let masks = m.default_masks.get("ilmpq2").unwrap();
    let mask_tensors = m.mask_tensors(masks);
    for &b in &m.infer_batches {
        let mut inputs = params.clone();
        inputs.extend(mask_tensors.iter().cloned());
        inputs.push(HostTensor::zeros(vec![
            b,
            m.data.height,
            m.data.width,
            m.data.channels,
        ]));
        let out = rt.run(&format!("infer_b{b}"), &inputs).unwrap();
        assert_eq!(out[0].shape, vec![b, m.classes]);
        assert!(out[0].as_f32().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn infer_batch_consistency() {
    // The same image must produce the same logits at batch 1 and batch 8.
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let masks = m.default_masks.get("ilmpq1").unwrap();
    let mask_tensors = m.mask_tensors(masks);
    let (x_test, _) = m.data.load_test().unwrap();
    let img = m.data.image_elems();

    let run = |batch: usize, data: Vec<f32>| {
        let mut inputs = params.clone();
        inputs.extend(mask_tensors.iter().cloned());
        inputs.push(HostTensor::f32(
            vec![batch, m.data.height, m.data.width, m.data.channels],
            data,
        ));
        rt.run(&format!("infer_b{batch}"), &inputs).unwrap()[0].clone()
    };

    let single = run(1, x_test[..img].to_vec());
    let mut batch8 = x_test[..img].to_vec();
    batch8.extend(std::iter::repeat(0.0).take(7 * img));
    let batched = run(8, batch8);
    let a = single.as_f32();
    let b = &batched.as_f32()[..m.classes];
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4, "batch inconsistency: {x} vs {y}");
    }
}

#[test]
fn masks_change_logits() {
    // The quantization config is a *runtime input*: different masks through
    // the same executable must change the output.
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let (x_test, _) = m.data.load_test().unwrap();
    let img = m.data.image_elems();
    let mut out = Vec::new();
    for ratio in ["pot4", "fixed4", "ilmpq2"] {
        let masks = m.default_masks.get(ratio).unwrap();
        let mut inputs = params.clone();
        inputs.extend(m.mask_tensors(masks));
        inputs.push(HostTensor::f32(
            vec![1, m.data.height, m.data.width, m.data.channels],
            x_test[..img].to_vec(),
        ));
        out.push(rt.run("infer_b1", &inputs).unwrap()[0].clone());
    }
    let d01: f32 = out[0]
        .as_f32()
        .iter()
        .zip(out[1].as_f32())
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(d01 > 1e-4, "pot4 vs fixed4 logits identical — masks ignored");
}

#[test]
fn frozen_weights_match_masked_inference() {
    // freeze(params, masks) through infer_frozen must equal (params, masks)
    // through the fake-quant infer path — the idempotence guarantee the
    // serving fast path relies on.
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let masks = m.default_masks.get("ilmpq2").unwrap();
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    let frozen = ilmpq::quant::freeze::freeze_params(&params, &names, masks);
    let (x_test, _) = m.data.load_test().unwrap();
    let img = m.data.image_elems();
    let x = HostTensor::f32(
        vec![1, m.data.height, m.data.width, m.data.channels],
        x_test[..img].to_vec(),
    );

    let mut masked_in = params.clone();
    masked_in.extend(m.mask_tensors(masks));
    masked_in.push(x.clone());
    let masked = rt.run("infer_b1", &masked_in).unwrap()[0].clone();

    let mut frozen_in = frozen;
    frozen_in.push(x);
    let fast = rt.run("infer_frozen_b1", &frozen_in).unwrap()[0].clone();

    for (a, b) in masked.as_f32().iter().zip(fast.as_f32()) {
        assert!((a - b).abs() < 1e-3, "frozen path diverged: {a} vs {b}");
    }
}

#[test]
fn train_step_learns() {
    let Some(rt) = runtime_or_skip() else { return };
    let masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    let mut tr = Trainer::new(&rt, &masks, 7).unwrap();
    let mut first = None;
    for _ in 0..100 {
        let (loss, _) = tr.step().unwrap();
        first.get_or_insert(loss);
    }
    let early = first.unwrap();
    let late = tr.recent_loss(10);
    // The dataset noise is calibrated for scheme separation, so 100 steps
    // won't converge — but the loss must have crossed below its start and
    // the ln(10)=2.303 chance floor (deterministic: seed-fixed batches).
    // Full convergence is exercised by `train_qat --steps 400` (~65% test
    // accuracy; see EXPERIMENTS.md).
    assert!(
        late < early.min(2.30),
        "loss did not drop: {early} -> {late}"
    );
}

#[test]
fn eval_batch_matches_trainer_eval() {
    let Some(rt) = runtime_or_skip() else { return };
    let masks = rt.manifest.default_masks.get("fixed4").unwrap().clone();
    let tr = Trainer::new(&rt, &masks, 3).unwrap();
    let ev = tr.evaluate().unwrap();
    assert!(ev.loss.is_finite());
    assert!((0.0..=1.0).contains(&ev.acc));
    // Untrained model ~ chance accuracy.
    assert!(ev.acc < 0.5, "untrained acc {}", ev.acc);
}

#[test]
fn rust_hessian_estimator_properties() {
    // At He-init the filters of a layer are iid draws, so the true
    // per-filter eigenvalue spectrum is nearly flat and the top-k ranking
    // is probe-dependent (the paper ranks a *pretrained* model, where
    // filters genuinely differ). What the estimator must guarantee:
    //  (a) deterministic given the seed,
    //  (b) eigenvalue estimates are dominated by positive curvature,
    //  (c) agreement with the Python estimator beats the chance rate.
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let eigs = filter_eigs(&rt, &params, 6, 11).unwrap();
    let eigs2 = filter_eigs(&rt, &params, 6, 11).unwrap();
    let mut chance = 0.0;
    let mut overlap = 0.0;
    let mut positive = 0usize;
    let mut total = 0usize;
    for (name, py_eigs) in &m.eigs {
        let rust_eigs = eigs.get(name).unwrap();
        assert_eq!(rust_eigs, eigs2.get(name).unwrap(), "{name}: nondeterministic");
        overlap += top_k_overlap(rust_eigs, py_eigs, 3);
        chance += 3.0 / rust_eigs.len() as f64;
        positive += rust_eigs.iter().filter(|&&e| e > 0.0).count();
        total += rust_eigs.len();
    }
    let n = m.eigs.len() as f64;
    assert!(
        positive as f64 / total as f64 > 0.6,
        "negative-curvature dominated: {positive}/{total}"
    );
    assert!(
        overlap / n > chance / n,
        "agreement {:.3} not above chance {:.3}",
        overlap / n,
        chance / n
    );
}

#[test]
fn serving_end_to_end() {
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let masks = m.default_masks.get("ilmpq2").unwrap().clone();
    let server = Server::start_pjrt(
        rt.clone(),
        params,
        &masks,
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(2),
            device: "xc7z045".into(),
            // plan: None — start_pjrt derives it from the masks argument.
            ..Default::default()
        },
    )
    .unwrap();
    let (x_test, _) = m.data.load_test().unwrap();
    let img = m.data.image_elems();
    let n = 40;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(x_test[i * img..(i + 1) * img].to_vec()))
        .collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("response")
            .expect("typed-ok reply");
        assert_eq!(resp.logits.len(), m.classes);
        assert!(resp.pred < m.classes);
        assert!(resp.sim_fpga > Duration::ZERO);
        ok += 1;
    }
    let metrics = server.stop();
    assert_eq!(ok, n);
    assert_eq!(
        ilmpq::coordinator::Metrics::get(&metrics.requests_done),
        n as u64
    );
    assert!(metrics.batch_occupancy() > 0.0);
}
