//! Shape tests over the experiment harness: the reproduced Table I must
//! preserve the paper's qualitative structure (who wins, where crossovers
//! fall, roughly what factors). These are the acceptance criteria from
//! DESIGN.md §4, enforced in CI. No artifacts needed — pure simulation.

use ilmpq::coordinator::ratio_search;
use ilmpq::experiments::table1;
use ilmpq::fpga::DeviceModel;
use ilmpq::model::resnet18;

#[test]
fn ilmpq_is_best_row_on_both_devices() {
    for (d, rows) in table1::run_all() {
        let best = rows
            .iter()
            .max_by(|a, b| a.sim.throughput_gops.partial_cmp(&b.sim.throughput_gops).unwrap())
            .unwrap();
        assert!(best.cfg.label.starts_with("ILMPQ"), "{}: {}", d.name, best.cfg.label);
        // ... and also wins accuracy in the paper — the double win is the
        // paper's whole point; hardware side checked here.
    }
}

#[test]
fn headline_speedups_within_30_percent_of_paper() {
    for (d, rows) in table1::run_all() {
        let paper = if d.name == "xc7z020" { 3.01 } else { 3.65 };
        let s = table1::speedup(&rows);
        let rel = (s - paper).abs() / paper;
        assert!(rel < 0.30, "{}: speedup {s:.2} vs paper {paper} ({rel:.2})", d.name);
    }
}

#[test]
fn ilmpq_cells_within_15_percent_of_paper() {
    // The two ILMPQ rows are the paper's headline cells; hold them tighter.
    for (d, rows) in table1::run_all() {
        let ilmpq = rows.iter().find(|r| r.cfg.label.starts_with("ILMPQ")).unwrap();
        let err = ilmpq.throughput_rel_err().unwrap();
        assert!(err < 0.15, "{}: ILMPQ throughput err {err:.2}", d.name);
    }
}

#[test]
fn crossover_pot_beats_fixed_everywhere() {
    // Table I's consistent crossover: every PoT-bearing row out-throughputs
    // the all-fixed rows on both boards.
    for (d, rows) in table1::run_all() {
        let fixed_best = rows
            .iter()
            .filter(|r| r.cfg.ratio.pot4 == 0.0)
            .map(|r| r.sim.throughput_gops)
            .fold(0.0f64, f64::max);
        let pot_worst = rows
            .iter()
            .filter(|r| r.cfg.ratio.pot4 >= 50.0 && !r.cfg.first_last_8bit)
            .map(|r| r.sim.throughput_gops)
            .fold(f64::INFINITY, f64::min);
        assert!(
            pot_worst > fixed_best,
            "{}: pot {pot_worst} vs fixed {fixed_best}",
            d.name
        );
    }
}

#[test]
fn first_last_quantization_always_helps_hardware() {
    // Paper rows (1) vs (2), (3) vs (4), (5) vs (6): removing the 8-bit
    // first/last engines always raises throughput.
    for (_, rows) in table1::run_all() {
        for (fl8, quant) in [("(1)", "(2)"), ("(3)", "(4)"), ("(5)", "(6)")] {
            let a = rows.iter().find(|r| r.cfg.label.starts_with(fl8)).unwrap();
            let b = rows.iter().find(|r| r.cfg.label.starts_with(quant)).unwrap();
            assert!(
                b.sim.throughput_gops > a.sim.throughput_gops,
                "{} !> {}",
                b.cfg.label,
                a.cfg.label
            );
        }
    }
}

#[test]
fn ratio_search_optima_near_paper() {
    // Paper: 60:35:5 (Z020), 65:30:5 (Z045). Allow +/-10 points of PoT.
    let net = resnet18();
    let z20 = ratio_search::search_default(&net, &DeviceModel::xc7z020());
    let z45 = ratio_search::search_default(&net, &DeviceModel::xc7z045());
    assert!(
        (z20.best.ratio.pot4 - 60.0).abs() <= 10.0,
        "z020 optimum {}",
        z20.best.ratio.label()
    );
    assert!(
        (z45.best.ratio.pot4 - 65.0).abs() <= 10.0,
        "z045 optimum {}",
        z45.best.ratio.label()
    );
    // The larger device's optimum leans at least as PoT-heavy.
    assert!(z45.best.ratio.pot4 >= z20.best.ratio.pot4 - 2.0);
}

#[test]
fn utilization_columns_track_paper_trends() {
    for (d, rows) in table1::run_all() {
        // Fixed-only rows: low-ish LUT; PoT rows: high LUT, low DSP when no
        // fixed work exists.
        let fixed = rows.iter().find(|r| r.cfg.label.starts_with("(2)")).unwrap();
        let pot = rows.iter().find(|r| r.cfg.label.starts_with("(4)")).unwrap();
        assert!(pot.sim.lut_util > fixed.sim.lut_util, "{}", d.name);
        assert!(pot.sim.dsp_util < 0.3, "{}: {}", d.name, pot.sim.dsp_util);
        assert!((fixed.sim.dsp_util - 1.0).abs() < 1e-9, "{}", d.name);
    }
}
