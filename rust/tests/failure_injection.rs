//! Failure injection: the runtime must fail loudly and precisely — a wrong
//! shape, a truncated binary, or a corrupt manifest must produce a clear
//! error, never a PJRT abort or silent garbage. The runtime-backed tests
//! require `make artifacts` + the `pjrt` feature and skip with a note when
//! either is missing; the pure manifest/binary-format tests always run.

use ilmpq::runtime::{HostTensor, Manifest, Runtime};

mod common;

fn runtime_or_skip() -> Option<Runtime> {
    common::runtime_or_skip("failure injection")
}

#[test]
fn wrong_input_count_is_an_error() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.run("infer_b1", &[]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("expected") && msg.contains("inputs"), "{msg}");
}

#[test]
fn wrong_input_shape_is_an_error_naming_the_input() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let spec = m.artifact("infer_b1").unwrap();
    // Correct count, but the image tensor has the wrong spatial dims.
    let mut inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|io| HostTensor::zeros(io.shape.clone()))
        .collect();
    let last = inputs.len() - 1;
    inputs[last] = HostTensor::zeros(vec![1, 4, 4, 3]);
    let err = rt.run("infer_b1", &inputs).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shape") && msg.contains('x'), "{msg}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.run("infer_b4096", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("not in manifest"));
}

#[test]
fn missing_manifest_dir_is_a_clear_error() {
    let err = Manifest::load(std::path::Path::new("/nonexistent/artifacts")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn corrupt_manifest_json_is_a_parse_error() {
    let dir = std::env::temp_dir().join("ilmpq_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{\"model\": [unterminated").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("json error"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_params_file_is_detected() {
    // Copy the real artifacts dir contents we need, truncate params_init.
    let src = Manifest::default_dir();
    if !src.join("manifest.json").exists() {
        eprintln!("SKIP truncated_params_file_is_detected (no artifacts)");
        return;
    }
    let dir = std::env::temp_dir().join("ilmpq_truncated_params");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(src.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let params = std::fs::read(src.join("params_init.bin")).unwrap();
    std::fs::write(dir.join("params_init.bin"), &params[..params.len() / 2]).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let err = m.load_init_params().unwrap_err();
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn misaligned_binary_is_detected() {
    let dir = std::env::temp_dir().join("ilmpq_misaligned");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("x.bin");
    std::fs::write(&p, [0u8; 7]).unwrap();
    let err = ilmpq::runtime::tensor::read_f32_file(&p).unwrap_err();
    assert!(format!("{err:#}").contains("multiple of 4"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mask_tensor_row_mismatch_panics_with_layer_name() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    masks.layers[0].is8.push(1.0); // corrupt: one extra row
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        rt.manifest.mask_tensors(&masks)
    }));
    assert!(result.is_err(), "row mismatch must not be silently accepted");
}

#[test]
fn unknown_plan_name_lists_available_plans() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.manifest.plan("bogus").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bogus") && msg.contains("ilmpq2"), "{msg}");
}

#[test]
fn server_rejects_mismatched_plan() {
    use ilmpq::coordinator::{ServeConfig, Server};
    use std::sync::Arc;
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    let params = rt.manifest.load_init_params().unwrap();
    let masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    // A corrupt plan (extra row in one layer) must fail validation at
    // startup instead of driving the sim overlay / pack with bad geometry.
    let mut plan = rt.manifest.plan("ilmpq2").unwrap();
    plan.masks.layers[0].is8.push(0.0);
    plan.masks.layers[0].is_pot.push(0.0);
    let cfg = ServeConfig { plan: Some(plan), ..Default::default() };
    let err = Server::start_pjrt(rt, params, &masks, cfg).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("plan") && msg.contains("rows"), "{msg}");
}

#[test]
fn server_rejects_unknown_device() {
    use ilmpq::coordinator::{ServeConfig, Server};
    use std::sync::Arc;
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    let params = rt.manifest.load_init_params().unwrap();
    let masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    let cfg = ServeConfig { device: "xc7z999".into(), ..Default::default() };
    let err = Server::start_pjrt(rt, params, &masks, cfg).err().expect("must fail");
    assert!(format!("{err:#}").contains("unknown device"));
}
