//! Failure injection: the runtime must fail loudly and precisely — a wrong
//! shape, a truncated binary, or a corrupt manifest must produce a clear
//! error, never a PJRT abort or silent garbage. The runtime-backed tests
//! require `make artifacts` + the `pjrt` feature and skip with a note when
//! either is missing; the pure manifest/binary-format tests always run.
//!
//! The second half drives every [`FaultyBackend`] fault mode — injected
//! errors, bursts, panics, garbage logits, stalls — through the *serving
//! pipeline* on the synthetic fixture, so the supervised-execution
//! guarantees (typed errors, watchdog abandonment, slot recovery) are
//! exercised artifact-free under `--no-default-features`.

use std::sync::Arc;
use std::time::Duration;

use ilmpq::backend::{FaultSpec, FaultyBackend};
use ilmpq::coordinator::{loadgen, ServeConfig, ServeError, Server};
use ilmpq::runtime::{HostTensor, Manifest, Runtime};
use ilmpq::util::Rng;

mod common;

fn runtime_or_skip() -> Option<Runtime> {
    common::runtime_or_skip("failure injection")
}

#[test]
fn wrong_input_count_is_an_error() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.run("infer_b1", &[]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("expected") && msg.contains("inputs"), "{msg}");
}

#[test]
fn wrong_input_shape_is_an_error_naming_the_input() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    let spec = m.artifact("infer_b1").unwrap();
    // Correct count, but the image tensor has the wrong spatial dims.
    let mut inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|io| HostTensor::zeros(io.shape.clone()))
        .collect();
    let last = inputs.len() - 1;
    inputs[last] = HostTensor::zeros(vec![1, 4, 4, 3]);
    let err = rt.run("infer_b1", &inputs).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shape") && msg.contains('x'), "{msg}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.run("infer_b4096", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("not in manifest"));
}

#[test]
fn missing_manifest_dir_is_a_clear_error() {
    let err = Manifest::load(std::path::Path::new("/nonexistent/artifacts")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn corrupt_manifest_json_is_a_parse_error() {
    let dir = std::env::temp_dir().join("ilmpq_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{\"model\": [unterminated").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("json error"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_params_file_is_detected() {
    // Copy the real artifacts dir contents we need, truncate params_init.
    let src = Manifest::default_dir();
    if !src.join("manifest.json").exists() {
        eprintln!("SKIP truncated_params_file_is_detected (no artifacts)");
        return;
    }
    let dir = std::env::temp_dir().join("ilmpq_truncated_params");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(src.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let params = std::fs::read(src.join("params_init.bin")).unwrap();
    std::fs::write(dir.join("params_init.bin"), &params[..params.len() / 2]).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let err = m.load_init_params().unwrap_err();
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn misaligned_binary_is_detected() {
    let dir = std::env::temp_dir().join("ilmpq_misaligned");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("x.bin");
    std::fs::write(&p, [0u8; 7]).unwrap();
    let err = ilmpq::runtime::tensor::read_f32_file(&p).unwrap_err();
    assert!(format!("{err:#}").contains("multiple of 4"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mask_tensor_row_mismatch_panics_with_layer_name() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    masks.layers[0].is8.push(1.0); // corrupt: one extra row
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        rt.manifest.mask_tensors(&masks)
    }));
    assert!(result.is_err(), "row mismatch must not be silently accepted");
}

#[test]
fn unknown_plan_name_lists_available_plans() {
    let Some(rt) = runtime_or_skip() else { return };
    let err = rt.manifest.plan("bogus").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bogus") && msg.contains("ilmpq2"), "{msg}");
}

#[test]
fn server_rejects_mismatched_plan() {
    use ilmpq::coordinator::{ServeConfig, Server};
    use std::sync::Arc;
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    let params = rt.manifest.load_init_params().unwrap();
    let masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    // A corrupt plan (extra row in one layer) must fail validation at
    // startup instead of driving the sim overlay / pack with bad geometry.
    let mut plan = rt.manifest.plan("ilmpq2").unwrap();
    plan.masks.layers[0].is8.push(0.0);
    plan.masks.layers[0].is_pot.push(0.0);
    let cfg = ServeConfig { plan: Some(plan), ..Default::default() };
    let err = Server::start_pjrt(rt, params, &masks, cfg).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("plan") && msg.contains("rows"), "{msg}");
}

// ---------------------------------------------------------------------------
// FaultyBackend → serving pipeline, artifact-free

/// A serving stack over the synthetic fixture with `spec` faults injected
/// between the serving loop and a healthy qgemm backend.
fn faulty_server(plan_name: &str, spec: FaultSpec, cfg: ServeConfig) -> (Server, usize) {
    let (m, inner, plan) = loadgen::synth_fixture("qgemm", plan_name, Some(1), 41).unwrap();
    let be = Arc::new(FaultyBackend::new(inner, spec));
    let cfg = ServeConfig { plan: Some(plan), ..cfg };
    let img = m.data.image_elems();
    (Server::start(&m, be, cfg).unwrap(), img)
}

fn one_request(server: &Server, img: usize, rng: &mut Rng) -> Result<(), ServeError> {
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    server
        .submit(image)
        .recv_timeout(Duration::from_secs(30))
        .expect("every admitted request must be answered")
        .map(|_| ())
}

#[test]
fn injected_backend_error_becomes_a_typed_reply() {
    let spec = FaultSpec { seed: 1, error_prob: 1.0, ..FaultSpec::default() };
    let (server, img) = faulty_server("fie", spec, ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut rng = Rng::new(2);
    match one_request(&server, img, &mut rng) {
        Err(ServeError::BackendFailed(msg)) => {
            assert!(msg.contains("injected fault"), "{msg}")
        }
        other => panic!("expected BackendFailed, got {other:?}"),
    }
    server.stop();
}

#[test]
fn failure_burst_fails_leading_batches_then_recovers() {
    // Burst of 2 at the head of an effectively-infinite period: the first
    // two batches fail, everything after runs clean.
    let spec = FaultSpec {
        seed: 3,
        burst_period: u64::MAX,
        burst_len: 2,
        ..FaultSpec::default()
    };
    let (server, img) = faulty_server("fib", spec, ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut rng = Rng::new(4);
    let outcomes: Vec<bool> =
        (0..5).map(|_| one_request(&server, img, &mut rng).is_ok()).collect();
    assert_eq!(outcomes, vec![false, false, true, true, true]);
    server.stop();
}

#[test]
fn injected_panic_is_contained_as_a_failed_batch() {
    let spec = FaultSpec { seed: 5, panic_prob: 1.0, ..FaultSpec::default() };
    let (server, img) = faulty_server("fip", spec, ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut rng = Rng::new(6);
    match one_request(&server, img, &mut rng) {
        Err(ServeError::BackendFailed(msg)) => {
            assert!(msg.contains("panicked"), "{msg}")
        }
        other => panic!("expected contained panic, got {other:?}"),
    }
    // The worker that contained the panic still serves (slot recovered,
    // thread alive): a second request gets a real answer too.
    assert!(one_request(&server, img, &mut rng).is_err());
    server.stop();
}

#[test]
fn garbage_logits_are_rejected_not_served() {
    // garbage_prob 1.0 corrupts every batch after the inner run (NaN fill
    // on even batch indices, truncation on odd): output validation must
    // turn both into BackendFailed — never Ok logits with NaN inside.
    let spec = FaultSpec { seed: 7, garbage_prob: 1.0, ..FaultSpec::default() };
    let (server, img) = faulty_server("fig", spec, ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    });
    let mut rng = Rng::new(8);
    for _ in 0..2 {
        match one_request(&server, img, &mut rng) {
            Err(ServeError::BackendFailed(msg)) => assert!(
                msg.contains("non-finite") || msg.contains("malformed"),
                "{msg}"
            ),
            other => panic!("garbage must not be served: {other:?}"),
        }
    }
    server.stop();
}

#[test]
fn stall_trips_the_watchdog_and_slots_recover() {
    // Every batch stalls 2s; the 50ms watchdog must abandon it, answer
    // Timeout, and release the queue slot — at queue_depth 1, a follow-up
    // request still being *admitted* (Timeout, not QueueFull) proves the
    // slot accounting recovered from the abandoned execution.
    let spec =
        FaultSpec { seed: 9, stall_prob: 1.0, stall_ms: 2_000, ..FaultSpec::default() };
    let (server, img) = faulty_server("fis", spec, ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: 1,
        execute_deadline: Some(Duration::from_millis(50)),
        ..Default::default()
    });
    let mut rng = Rng::new(10);
    for round in 0..2 {
        match one_request(&server, img, &mut rng) {
            Err(ServeError::Timeout { deadline_ms }) => assert_eq!(deadline_ms, 50),
            other => panic!("round {round}: expected Timeout, got {other:?}"),
        }
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(ilmpq::coordinator::Metrics::get(&metrics.requests_timeout), 2);
    assert_eq!(ilmpq::coordinator::Metrics::get(&metrics.batches_timeout), 2);
}

#[test]
fn fault_spec_loads_from_json_file() {
    let dir = std::env::temp_dir().join("ilmpq_fault_spec");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spec.json");
    std::fs::write(&path, FaultSpec::chaos(17).to_json().to_string_compact()).unwrap();
    let loaded = FaultSpec::load(&path).unwrap();
    assert_eq!(loaded, FaultSpec::chaos(17));
    // A spec that fails validation is rejected at load time.
    let bad = FaultSpec { panic_prob: 2.0, ..FaultSpec::default() };
    std::fs::write(&path, bad.to_json().to_string_compact()).unwrap();
    assert!(FaultSpec::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn faulty_registry_key_builds_the_wrapped_fixture() {
    // `--backend faulty:qgemm` flows through the same fixture recipe as any
    // other registry name (chaos schedule by default).
    let (_m, be, _plan) = loadgen::synth_fixture("faulty:qgemm", "frk", Some(1), 43).unwrap();
    assert_eq!(be.name(), "faulty:qgemm");
}

#[test]
fn server_rejects_unknown_device() {
    use ilmpq::coordinator::{ServeConfig, Server};
    use std::sync::Arc;
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    let params = rt.manifest.load_init_params().unwrap();
    let masks = rt.manifest.default_masks.get("ilmpq2").unwrap().clone();
    let cfg = ServeConfig { device: "xc7z999".into(), ..Default::default() };
    let err = Server::start_pjrt(rt, params, &masks, cfg).err().expect("must fail");
    assert!(format!("{err:#}").contains("unknown device"));
}
