//! End-to-end smoke tests for the HTTP/1.1 front end (`coordinator::http`)
//! over real loopback sockets, on the artifact-free synthetic qgemm
//! fixture — so the whole network path (accept pool, request parsing,
//! admission pipeline, typed-error → status mapping, reply serialization)
//! runs in the `--no-default-features` CI leg.
//!
//! Pinned here (the acceptance contract for `ilmpq serve --listen`):
//!
//! * concurrent clients get correct logits over the wire;
//! * the four typed-error mappings: malformed body / wrong-length image →
//!   `400`, queue-full at depth → `429`, failing backend → `500`,
//!   draining server → `503`;
//! * a malformed or stalled HTTP request is answered (or timed out) and
//!   **never wedges a handler** — the next request on a fresh connection
//!   still succeeds;
//! * the remote load generator (`loadgen::run_remote`, `ilmpq loadgen
//!   --url`) reproduces the in-process outcome classes over the wire;
//! * the raw little-endian f32 encoding (`application/x-raw-f32`) is
//!   bit-identical with JSON end-to-end, malformed raw bodies bounce with
//!   `bad_tensor_size` without touching their batch neighbours, and an
//!   unknown Content-Type maps to 415.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use ilmpq::backend::{BatchOutput, InferenceBackend};
use ilmpq::coordinator::{
    loadgen, HttpClient, HttpConfig, HttpServer, HttpTarget, ServeConfig, Server,
    RAW_CONTENT_TYPE,
};
use ilmpq::runtime::Manifest;
use ilmpq::util::{Json, Rng};

/// Synthetic manifest + qgemm backend + running server + HTTP front end on
/// an ephemeral loopback port.
fn start_front(
    plan_name: &str,
    mut serve_cfg: ServeConfig,
    http_workers: usize,
) -> (HttpServer, Manifest) {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", plan_name, Some(2), 11).unwrap();
    serve_cfg.plan = Some(plan);
    start_front_with(&m, be, serve_cfg, http_workers)
}

fn start_front_with(
    m: &Manifest,
    be: Arc<dyn InferenceBackend>,
    serve_cfg: ServeConfig,
    http_workers: usize,
) -> (HttpServer, Manifest) {
    let server = Server::start(m, be, serve_cfg).unwrap();
    let front = HttpServer::start(
        server,
        m,
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: http_workers,
            ..Default::default()
        },
    )
    .unwrap();
    (front, m.clone())
}

fn client_for(front: &HttpServer) -> HttpClient {
    let target = HttpTarget::parse(&format!("http://{}", front.local_addr())).unwrap();
    HttpClient::connect(&target, Duration::from_secs(30))
}

fn infer_body(image: &[f32]) -> String {
    Json::obj(vec![(
        "image",
        Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
    )])
    .to_string_compact()
}

fn normal_image(img: usize, rng: &mut Rng) -> Vec<f32> {
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    image
}

/// The raw wire encoding: the image verbatim as little-endian f32 bytes.
fn raw_body(image: &[f32]) -> Vec<u8> {
    let mut b = Vec::with_capacity(image.len() * 4);
    for v in image {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b
}

fn logits_of(body: &str) -> Vec<f32> {
    Json::parse(body)
        .unwrap()
        .get("logits")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

fn kind_of(body: &str) -> Option<String> {
    Json::parse(body)
        .ok()?
        .get("kind")
        .and_then(Json::as_str)
        .map(str::to_string)
}

#[test]
fn concurrent_clients_get_logits_over_the_wire() {
    let (front, m) = start_front(
        "web",
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
        8,
    );
    let img = m.data.image_elems();
    let classes = m.classes;

    // healthz advertises the model geometry (what loadgen --url probes).
    let mut probe = client_for(&front);
    let (code, body) = probe.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let health = Json::parse(&body).unwrap();
    assert_eq!(health.get("image_elems").and_then(Json::as_usize), Some(img));
    assert_eq!(health.get("classes").and_then(Json::as_usize), Some(classes));

    // 4 concurrent keep-alive clients x 8 sequential requests each.
    let addr = front.local_addr();
    let handles: Vec<_> = (0..4u64)
        .map(|t| {
            std::thread::spawn(move || {
                let target = HttpTarget::parse(&format!("http://{addr}")).unwrap();
                let mut client = HttpClient::connect(&target, Duration::from_secs(30));
                let mut rng = Rng::new(100 + t);
                let mut ok = 0usize;
                for _ in 0..8 {
                    let image = {
                        let mut v = vec![0f32; img];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    };
                    let (code, body) =
                        client.request("POST", "/v1/infer", Some(&infer_body(&image))).unwrap();
                    assert_eq!(code, 200, "{body}");
                    let j = Json::parse(&body).unwrap();
                    let logits = j.get("logits").and_then(Json::as_arr).unwrap();
                    assert_eq!(logits.len(), classes);
                    let pred = j.get("pred").and_then(Json::as_usize).unwrap();
                    assert!(pred < classes);
                    let qw = j.get("queue_wait_s").and_then(Json::as_f64).unwrap();
                    let e2e = j.get("e2e_s").and_then(Json::as_f64).unwrap();
                    assert!(qw <= e2e, "queue_wait {qw} must bound below e2e {e2e}");
                    ok += 1;
                }
                ok
            })
        })
        .collect();
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 32);

    // /v1/metrics reflects the served traffic and parses strictly.
    let (code, body) = probe.request("GET", "/v1/metrics", None).unwrap();
    assert_eq!(code, 200);
    let metrics = Json::parse(&body).expect("metrics endpoint must emit valid JSON");
    assert_eq!(
        metrics.get("requests_done").and_then(Json::as_usize),
        Some(32),
        "{body}"
    );
    assert!(!body.contains("inf"), "non-JSON token leaked into {body}");

    let final_metrics = front.stop();
    assert_eq!(final_metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(
        ilmpq::coordinator::Metrics::get(&final_metrics.requests_done),
        32
    );
}

#[test]
fn wire_logits_match_direct_backend_execution() {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", "par", Some(2), 17).unwrap();
    let (front, m) = start_front_with(
        &m,
        be.clone(),
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut rng = Rng::new(5);
    let image = normal_image(img, &mut rng);
    let reference = be.run_batch(&image, 1).unwrap();

    let mut client = client_for(&front);
    let (code, body) = client.request("POST", "/v1/infer", Some(&infer_body(&image))).unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("pred").and_then(Json::as_usize), Some(reference.preds[0]));
    let logits: Vec<f32> = j
        .get("logits")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    // f32 -> f64 -> shortest-roundtrip text -> f64 -> f32 is lossless, so
    // the wire must not perturb the numerics at all (== rather than
    // to_bits: the writer's integer fast path folds -0.0 into 0).
    assert_eq!(logits.len(), reference.logits.len());
    assert_eq!(
        logits, reference.logits,
        "wire logits diverged from direct execution"
    );
    front.stop();
}

#[test]
fn raw_and_json_encodings_are_bit_identical_over_the_wire() {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", "raw", Some(2), 37).unwrap();
    let (front, m) = start_front_with(
        &m,
        be.clone(),
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut rng = Rng::new(21);
    let image = normal_image(img, &mut rng);
    let reference = be.run_batch(&image, 1).unwrap();

    let mut client = client_for(&front);
    // Raw round-trip: little-endian f32 bytes in, logits out — matching
    // direct backend execution exactly (the body *is* the ImageBuf, no
    // textual round-trip anywhere on the ingest path).
    let (code, body) = client
        .request_bytes("POST", "/v1/infer", &raw_body(&image), RAW_CONTENT_TYPE)
        .unwrap();
    assert_eq!(code, 200, "{body}");
    let raw_logits = logits_of(&body);
    assert_eq!(raw_logits, reference.logits, "raw wire diverged from direct execution");
    assert_eq!(
        Json::parse(&body).unwrap().get("pred").and_then(Json::as_usize),
        Some(reference.preds[0])
    );

    // The same image as JSON: the f32 -> shortest-decimal -> f32 text trip
    // is lossless, so both encodings must produce bit-identical logits.
    let (code, body) = client.request("POST", "/v1/infer", Some(&infer_body(&image))).unwrap();
    assert_eq!(code, 200, "{body}");
    assert_eq!(
        logits_of(&body),
        raw_logits,
        "JSON and raw encodings must agree bit-for-bit"
    );
    front.stop();
}

#[test]
fn malformed_raw_bodies_bounce_alone_with_bit_correct_neighbours() {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", "rwb", Some(2), 41).unwrap();
    let (front, m) = start_front_with(
        &m,
        be.clone(),
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut rng = Rng::new(43);
    let good = normal_image(img, &mut rng);
    let reference = be.run_batch(&good, 1).unwrap();
    let mut client = client_for(&front);

    // Each malformed shape draws its 400 at the right layer, and a
    // well-formed request straight after still computes bit-correct logits
    // — a rejected body must never leak into anyone's batch.
    let truncated = raw_body(&good[..img / 2]);
    let mut ragged = raw_body(&good);
    ragged.pop(); // no longer a whole number of f32s (and short one byte)
    let mut oversized = raw_body(&good);
    oversized.extend_from_slice(&1.0f32.to_le_bytes());
    let mut poisoned = good.clone();
    poisoned[3] = f32::NAN;
    let cases: Vec<(Vec<u8>, &str, &str)> = vec![
        (truncated, "bad_tensor_size", "short body"),
        (ragged, "bad_tensor_size", "non-multiple-of-4 body"),
        (oversized, "bad_tensor_size", "wrong-length body"),
        // Right size, non-finite payload: decodes fine, then bounces off
        // *admission* — same class as its JSON twin.
        (raw_body(&poisoned), "invalid_input", "non-finite bytes"),
    ];
    for (bad, want_kind, what) in cases {
        let (code, reply) = client
            .request_bytes("POST", "/v1/infer", &bad, RAW_CONTENT_TYPE)
            .unwrap();
        assert_eq!(code, 400, "{what}: {reply}");
        assert_eq!(kind_of(&reply).as_deref(), Some(want_kind), "{what}: {reply}");
        let (code, reply) = client
            .request_bytes("POST", "/v1/infer", &raw_body(&good), RAW_CONTENT_TYPE)
            .unwrap();
        assert_eq!(code, 200, "neighbour after {what}: {reply}");
        assert_eq!(
            logits_of(&reply),
            reference.logits,
            "neighbour logits perturbed after {what}"
        );
    }

    // Unknown Content-Type: 415 naming both supported encodings.
    let (code, reply) = client
        .request_bytes("POST", "/v1/infer", &raw_body(&good), "application/x-protobuf")
        .unwrap();
    assert_eq!(code, 415, "{reply}");
    assert_eq!(kind_of(&reply).as_deref(), Some("unsupported_media_type"), "{reply}");
    let err = Json::parse(&reply).unwrap();
    let msg = err.get("error").and_then(Json::as_str).unwrap().to_string();
    assert!(
        msg.contains("application/json") && msg.contains(RAW_CONTENT_TYPE),
        "415 body must list the supported encodings: {reply}"
    );

    let metrics = front.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
}

#[test]
fn malformed_bodies_and_wrong_geometry_map_to_400() {
    let (front, m) = start_front(
        "bad",
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut client = client_for(&front);

    for (body, what) in [
        ("this is not json".to_string(), "non-JSON body"),
        ("{\"no_image\": 1}".to_string(), "missing image key"),
        ("{\"image\": \"zebra\"}".to_string(), "non-array image"),
        ("{\"image\": [1, \"x\"]}".to_string(), "non-numeric element"),
    ] {
        let (code, reply) = client.request("POST", "/v1/infer", Some(&body)).unwrap();
        assert_eq!(code, 400, "{what}: {reply}");
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("bad_request"), "{what}");
    }

    // Wrong-length image: decodes fine, then bounces off *admission* (the
    // batch-corruption class) — kind pins that it came from the pipeline.
    let short = vec![0.25f32; img / 2];
    let (code, reply) = client.request("POST", "/v1/infer", Some(&infer_body(&short))).unwrap();
    assert_eq!(code, 400, "{reply}");
    let j = Json::parse(&reply).unwrap();
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("invalid_input"), "{reply}");

    // Unknown route / method mapping.
    let (code, _) = client.request("GET", "/v1/nope", None).unwrap();
    assert_eq!(code, 404);
    let (code, _) = client.request("GET", "/v1/infer", None).unwrap();
    assert_eq!(code, 405);

    let metrics = front.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(ilmpq::coordinator::Metrics::get(&metrics.requests_done), 0);
}

/// Wraps a real backend, delaying every batch — makes the depth-4 queue
/// bound trip deterministically under a concurrent burst.
struct SlowBackend {
    inner: Arc<dyn InferenceBackend>,
    delay: Duration,
}

impl InferenceBackend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }

    fn supports_frozen(&self) -> bool {
        self.inner.supports_frozen()
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> anyhow::Result<BatchOutput> {
        std::thread::sleep(self.delay);
        self.inner.run_batch(images, batch)
    }
}

#[test]
fn queue_full_maps_to_429_under_burst() {
    let depth = 4usize;
    let (m, inner, plan) = loadgen::synth_fixture("qgemm", "ovl", Some(1), 23).unwrap();
    let be: Arc<dyn InferenceBackend> =
        Arc::new(SlowBackend { inner, delay: Duration::from_millis(150) });
    let (front, m) = start_front_with(
        &m,
        be,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: depth,
            plan: Some(plan),
            ..Default::default()
        },
        16,
    );
    let img = m.data.image_elems();
    let addr = front.local_addr();

    // 16 truly concurrent one-shot clients: the backend needs >=150ms per
    // batch, so all 16 submissions land inside one batch window and at
    // most `depth` can be in the system — the rest must see 429.
    let handles: Vec<_> = (0..16u64)
        .map(|t| {
            std::thread::spawn(move || {
                let target = HttpTarget::parse(&format!("http://{addr}")).unwrap();
                let mut client = HttpClient::connect(&target, Duration::from_secs(30));
                let mut rng = Rng::new(1000 + t);
                let image = {
                    let mut v = vec![0f32; img];
                    rng.fill_normal(&mut v, 1.0);
                    v
                };
                client.request("POST", "/v1/infer", Some(&infer_body(&image))).unwrap()
            })
        })
        .collect();
    let (mut done, mut shed) = (0usize, 0usize);
    for h in handles {
        let (code, body) = h.join().unwrap();
        match code {
            200 => done += 1,
            429 => {
                let j = Json::parse(&body).unwrap();
                assert_eq!(j.get("kind").and_then(Json::as_str), Some("queue_full"));
                shed += 1;
            }
            other => panic!("expected 200 or 429, got {other}: {body}"),
        }
    }
    assert_eq!(done + shed, 16);
    assert!(done >= 1, "the first depth-worth must complete");
    assert!(shed >= 1, "a 16-way burst at depth {depth} must shed");
    front.stop();
}

/// A backend whose every batch errors — over the wire this must surface as
/// a 500 with the `backend_failed` kind.
struct FailingBackend;

impl InferenceBackend for FailingBackend {
    fn name(&self) -> &str {
        "failing"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn run_batch(&self, _images: &[f32], _batch: usize) -> anyhow::Result<BatchOutput> {
        anyhow::bail!("injected backend failure")
    }
}

#[test]
fn backend_failure_maps_to_500() {
    let (m, _unused, plan) = loadgen::synth_fixture("qgemm", "flk", Some(1), 29).unwrap();
    let (front, m) = start_front_with(
        &m,
        Arc::new(FailingBackend),
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut rng = Rng::new(3);
    let mut client = client_for(&front);
    let (code, body) = client
        .request("POST", "/v1/infer", Some(&infer_body(&normal_image(img, &mut rng))))
        .unwrap();
    assert_eq!(code, 500, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("backend_failed"));
    assert!(
        j.get("error").and_then(Json::as_str).unwrap().contains("injected"),
        "{body}"
    );
    front.stop();
}

#[test]
fn draining_server_maps_to_503_while_http_stays_up() {
    let (front, m) = start_front(
        "drn",
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        2,
    );
    let img = m.data.image_elems();
    let mut rng = Rng::new(7);
    let mut client = client_for(&front);

    // Sanity: serving before the drain.
    let (code, _) = client
        .request("POST", "/v1/infer", Some(&infer_body(&normal_image(img, &mut rng))))
        .unwrap();
    assert_eq!(code, 200);

    // Graceful-drain front half: the admission pipeline refuses new work
    // while the HTTP layer keeps answering (the 503 is the answer).
    front.server().begin_shutdown();
    let (code, body) = client
        .request("POST", "/v1/infer", Some(&infer_body(&normal_image(img, &mut rng))))
        .unwrap();
    assert_eq!(code, 503, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("shutting_down"));

    // healthz still answers during the drain (liveness), but reports
    // not-ready with a 503 so load balancers stop routing here.
    let (code, hbody) = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 503, "{hbody}");
    let h = Json::parse(&hbody).unwrap();
    assert_eq!(h.get("live"), Some(&Json::Bool(true)), "{hbody}");
    assert_eq!(h.get("ready"), Some(&Json::Bool(false)), "{hbody}");
    assert_eq!(h.get("draining"), Some(&Json::Bool(true)), "{hbody}");
    front.stop();
}

#[test]
fn malformed_http_never_wedges_a_handler() {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", "mal", Some(2), 11).unwrap();
    let server = Server::start(
        &m,
        be,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        },
    )
    .unwrap();
    let front = HttpServer::start(
        server,
        &m,
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            // One handler on purpose: if garbage wedged it, the follow-up
            // request could never be served.
            workers: 1,
            // Short receive budget so the stalled-request 408 fires well
            // inside the client-side read timeouts below.
            request_timeout: Duration::from_millis(500),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = front.local_addr();
    let img = m.data.image_elems();

    // 1. Complete-but-garbage request line: answered 400, connection closed.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GARBAGE REQUEST\r\n\r\n").unwrap();
        let mut reply = String::new();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_to_string(&mut reply).unwrap();
        assert!(
            reply.starts_with("HTTP/1.1 400"),
            "garbage must be answered 400: {reply:?}"
        );
    }

    // 2. Partial request that goes quiet: the handler must time it out
    //    (408) instead of waiting forever.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"POST /v1/infer HTTP/1.1\r\ncontent-le").unwrap();
        // No more bytes: the per-request receive budget expires and the
        // handler answers instead of holding the connection.
        let mut reply = String::new();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_to_string(&mut reply).unwrap();
        assert!(
            reply.starts_with("HTTP/1.1 408"),
            "stalled request must be timed out: {reply:?}"
        );
    }

    // 3. Declared body larger than the limit: bounced with 413 before any
    //    buffering.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"POST /v1/infer HTTP/1.1\r\ncontent-length: 999999999\r\n\r\n")
            .unwrap();
        let mut reply = String::new();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_to_string(&mut reply).unwrap();
        assert!(
            reply.starts_with("HTTP/1.1 413"),
            "oversized body must be refused: {reply:?}"
        );
    }

    // 4. The handler survived all of it: a well-formed request succeeds.
    let mut rng = Rng::new(9);
    let mut client = client_for(&front);
    let (code, body) = client
        .request("POST", "/v1/infer", Some(&infer_body(&normal_image(img, &mut rng))))
        .unwrap();
    assert_eq!(code, 200, "handler wedged by malformed traffic: {body}");
    front.stop();
}

#[test]
fn plan_endpoint_reports_the_active_plan() {
    let (m, be, plan) = loadgen::synth_fixture("qgemm", "pln", Some(1), 31).unwrap();
    let (front, _m) = start_front_with(
        &m,
        be,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan.clone()),
            ..Default::default()
        },
        2,
    );
    let mut client = client_for(&front);

    // GET /v1/plan advertises name, provenance, and scheme fractions —
    // exactly the precision configuration this server executes.
    let (code, body) = client.request("GET", "/v1/plan", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("name").and_then(Json::as_str), Some("pln"));
    assert_eq!(
        j.get("provenance").and_then(|p| p.get("kind")).and_then(Json::as_str),
        Some("synthetic"),
        "{body}"
    );
    let (p, f4, f8) = plan.total_fractions();
    let total = j.get("total").expect("total fractions object");
    for (key, want) in [("pot4", p), ("fixed4", f4), ("fixed8", f8)] {
        let got = total.get(key).and_then(Json::as_f64).unwrap();
        assert!(
            (got - want).abs() < 1e-9,
            "{key}: wire {got} vs in-memory {want}"
        );
    }
    assert_eq!(
        j.get("layers").and_then(Json::as_arr).map(<[Json]>::len),
        Some(plan.masks.layers.len())
    );

    // healthz names the active plan; method misuse maps like the others.
    let (code, hbody) = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200);
    let h = Json::parse(&hbody).unwrap();
    assert_eq!(h.get("plan").and_then(Json::as_str), Some("pln"), "{hbody}");
    let (code, _) = client.request("POST", "/v1/plan", Some("{}")).unwrap();
    assert_eq!(code, 405);
    front.stop();
}

#[test]
fn remote_loadgen_reproduces_outcome_classes_over_the_wire() {
    let (front, _m) = start_front(
        "rlg",
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        4,
    );
    let url = format!("http://{}", front.local_addr());
    let spec = loadgen::LoadSpec {
        requests: 24,
        rate: 0.0, // unpaced
        malformed_frac: 0.5,
        seed: 11,
        ..Default::default()
    };
    let (r, server_metrics) = loadgen::run_remote(&url, &spec, 3).unwrap();
    assert_eq!(r.lost, 0, "typed pipeline over the wire must answer every request");
    assert_eq!(r.slow, 0, "tiny run must drain inside the deadline");
    assert_eq!(
        r.done + r.invalid + r.shed + r.failed + r.shutdown + r.timeout + r.unavailable,
        r.requests
    );
    assert!(r.done > 0, "{r:?}");
    assert!(r.invalid > 0, "malformed_frac must produce 400s: {r:?}");
    assert!(r.goodput_rps > 0.0);
    // Server-reported timings rode along in every 200 body, and the
    // client-side round-trip was recorded alongside them.
    assert_eq!(r.e2e.n, r.done, "every reply must carry e2e_s: {r:?}");
    assert_eq!(r.client_rtt.n, r.done);
    assert!(r.e2e.p50 > 0.0);
    // The client round-trip spans a superset of the server's e2e interval.
    assert!(
        r.client_rtt.p50 >= r.e2e.p50 * 0.99,
        "rtt {} vs e2e {}",
        r.client_rtt.p50,
        r.e2e.p50
    );
    // The server-side snapshot rode along and agrees on the done count.
    assert_eq!(
        server_metrics.get("requests_done").and_then(Json::as_usize),
        Some(r.done),
        "{server_metrics:?}"
    );
    front.stop();
}
