//! Cross-language agreement: the Rust assignment/quantization substrate must
//! reproduce, bit-for-bit, what `python/compile/assign.py` wrote into the
//! manifest (default masks per ratio, from Hessian eigs + row variance at
//! the init weights). Requires `make artifacts`; without the artifacts the
//! tests skip with a note so the pure-CPU suite stays runnable everywhere.

use ilmpq::quant::{assign, gemm_rows, named_ratios};
use ilmpq::runtime::Manifest;

mod common;

fn manifest_or_skip() -> Option<Manifest> {
    common::manifest_or_skip("manifest agreement")
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(m) = manifest_or_skip() else { return };
    assert_eq!(m.model_name, "tinyresnet-16-32-64");
    assert_eq!(m.params.len(), 11);
    assert_eq!(m.quantized_layers.len(), 10);
    assert!(m.artifacts.contains_key("train_step"));
    assert!(m.artifacts.contains_key("infer_b1"));
    assert!(m.artifacts.contains_key("eval_batch"));
    assert!(m.artifacts.contains_key("hessian_hvp"));
    for (name, rows, fan_in) in &m.quantized_layers {
        assert!(*rows > 0 && *fan_in > 0, "{name}");
        assert_eq!(m.eigs.get(name).map(Vec::len), Some(*rows), "{name}");
    }
}

#[test]
fn init_params_match_manifest_shapes() {
    let Some(m) = manifest_or_skip() else { return };
    let params = m.load_init_params().unwrap();
    assert_eq!(params.len(), m.params.len());
    for (t, (name, shape)) in params.iter().zip(&m.params) {
        assert_eq!(&t.shape, shape, "{name}");
        // He init: finite, non-degenerate.
        let norm: f32 = t.as_f32().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm.is_finite(), "{name}");
        if name != "fc/b" {
            assert!(norm > 0.0, "{name}");
        }
    }
}

#[test]
fn dataset_loads_with_expected_shapes() {
    let Some(m) = manifest_or_skip() else { return };
    let (xtr, ytr) = m.data.load_train().unwrap();
    let (xte, yte) = m.data.load_test().unwrap();
    assert_eq!(xtr.len(), m.data.n_train * m.data.image_elems());
    assert_eq!(ytr.len(), m.data.n_train);
    assert_eq!(xte.len(), m.data.n_test * m.data.image_elems());
    assert_eq!(yte.len(), m.data.n_test);
    let classes = m.data.classes as i32;
    assert!(ytr.iter().all(|&y| (0..classes).contains(&y)));
    // Balanced-ish labels.
    let mut counts = vec![0usize; classes as usize];
    for &y in &ytr {
        counts[y as usize] += 1;
    }
    let min = *counts.iter().min().unwrap();
    assert!(min > m.data.n_train / classes as usize / 3, "{counts:?}");
}

#[test]
fn rust_assignment_reproduces_python_default_masks() {
    let Some(m) = manifest_or_skip() else { return };
    let params = m.load_init_params().unwrap();
    for (rname, ratio) in named_ratios() {
        let pyset = m
            .default_masks
            .get(rname)
            .unwrap_or_else(|| panic!("manifest missing ratio {rname}"));
        for (lname, _rows, _fan) in &m.quantized_layers {
            let idx = m.params.iter().position(|(n, _)| n == lname).unwrap();
            let w_rows = gemm_rows(&params[idx]);
            let eigs = m.eigs.get(lname).unwrap();
            let rust = assign::assign_layer(lname, &w_rows, eigs, ratio);
            let py = pyset.layer(lname).unwrap();
            assert_eq!(
                rust.is8, py.is8,
                "{rname}/{lname}: is8 masks disagree (Rust vs Python)"
            );
            assert_eq!(
                rust.is_pot, py.is_pot,
                "{rname}/{lname}: is_pot masks disagree (Rust vs Python)"
            );
        }
    }
}

#[test]
fn default_masks_respect_ratio_counts() {
    let Some(m) = manifest_or_skip() else { return };
    let ilmpq2 = m.default_masks.get("ilmpq2").unwrap();
    let (p, _f4, f8) = ilmpq2.total_fractions();
    assert!((p - 0.65).abs() < 0.08, "pot fraction {p}");
    assert!((f8 - 0.05).abs() < 0.05, "f8 fraction {f8}");
    for l in &ilmpq2.layers {
        let (_, _, n8) = l.counts();
        assert!(n8 >= 1, "{}: no 8-bit rescue row", l.layer);
    }
}

#[test]
fn eigs_identify_consistent_sensitive_filters() {
    // The is8 rows of ilmpq1 and ilmpq2 must be identical (same eigs, same
    // 5% budget) even though their PoT shares differ.
    let Some(m) = manifest_or_skip() else { return };
    let a = m.default_masks.get("ilmpq1").unwrap();
    let b = m.default_masks.get("ilmpq2").unwrap();
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.is8, lb.is8, "{}", la.layer);
    }
}
