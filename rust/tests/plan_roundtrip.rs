//! Quantization-plan acceptance tests — artifact-free, PJRT-free, so the
//! `--no-default-features` CI leg pins the whole plan lifecycle on every
//! push:
//!
//! * round-trip identity: derive → save → load yields a bit-identical
//!   `MaskSet` (the `ilmpq plan derive --out p.json` → `ilmpq serve --plan
//!   p.json` contract, exercised here through the same library calls);
//! * `QuantSource::PlanFile` serving is bit-identical to the in-memory
//!   derivation — same masks, same logits, end to end through the
//!   admission pipeline;
//! * `validate` rejects wrong layer names, wrong row counts, and
//!   overlapping `is8`/`is_pot` masks;
//! * `NamedRatio` resolution agrees with the manifest's legacy
//!   `default_masks` table on the synthetic fixture.

use std::time::Duration;

use ilmpq::coordinator::{loadgen, ServeConfig, Server};
use ilmpq::quant::{QuantPlan, QuantSource, Ratio};
use ilmpq::util::Rng;

fn tmp_plan_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ilmpq_plan_it_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("plan.json")
}

#[test]
fn derive_save_load_is_a_mask_identity() {
    let (m, _params, derived) = loadgen::synth_plan("rt", Ratio::new(65.0, 30.0, 5.0), 7);
    derived.validate(&m).unwrap();
    let path = tmp_plan_path("identity");
    derived.save(&path).unwrap();
    let loaded = QuantPlan::load(&path).unwrap();
    // Full structural equality — name, version, model, provenance, and the
    // mask set bit for bit (values are exactly 0.0/1.0, so JSON is exact).
    assert_eq!(loaded, derived);
    for (a, b) in loaded.masks.layers.iter().zip(&derived.masks.layers) {
        assert!(a.is8.iter().zip(&b.is8).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.is_pot.iter().zip(&b.is_pot).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn serving_a_plan_file_matches_the_in_memory_derivation() {
    // The acceptance path: `plan derive --synthetic --out p.json` then
    // `serve --plan p.json` must execute bit-identical masks to the
    // in-memory derivation at the same seed.
    let seed = 7u64;
    let ratio = Ratio::new(65.0, 30.0, 5.0);
    let (_m, _params, derived) = loadgen::synth_plan("acc", ratio, seed);
    let path = tmp_plan_path("serve");
    derived.save(&path).unwrap();

    // In-memory path: the named synthetic source generates the same plan.
    let (m_mem, be_mem, plan_mem) = loadgen::synth_fixture_source(
        "qgemm",
        &QuantSource::NamedRatio("acc".into()),
        Some(2),
        seed,
        true,
    )
    .unwrap();
    let plan_mem = plan_mem.unwrap();
    assert_eq!(plan_mem.masks, derived.masks, "synth_plan must be the NamedRatio recipe");

    // File path: what `ilmpq serve --plan p.json --synthetic` constructs.
    let (m_file, be_file, plan_file) = loadgen::synth_fixture_source(
        "qgemm",
        &QuantSource::PlanFile(path.clone()),
        Some(2),
        seed,
        true,
    )
    .unwrap();
    let plan_file = plan_file.unwrap();
    assert_eq!(plan_file.masks, derived.masks, "plan file masks must round-trip");

    // Same packed execution: identical logits through the whole admission
    // pipeline for the same workload.
    let img = m_mem.data.image_elems();
    assert_eq!(img, m_file.data.image_elems());
    let mut rng = Rng::new(99);
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    let direct_mem = be_mem.run_batch(&image, 1).unwrap();
    let direct_file = be_file.run_batch(&image, 1).unwrap();
    assert_eq!(direct_mem.preds, direct_file.preds);
    assert!(direct_mem
        .logits
        .iter()
        .zip(&direct_file.logits)
        .all(|(a, b)| a.to_bits() == b.to_bits()));

    let server = Server::start(
        &m_file,
        be_file,
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan_file.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(server.plan.as_ref().unwrap().masks, derived.masks);
    let reply = server
        .submit(image)
        .recv_timeout(Duration::from_secs(30))
        .expect("reply")
        .expect("plan-served request must succeed");
    assert_eq!(reply.pred, direct_mem.preds[0]);
    assert!(reply
        .logits
        .iter()
        .zip(&direct_mem.logits)
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    server.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn named_ratio_resolution_matches_the_legacy_table() {
    // `QuantSource::NamedRatio` on a manifest must agree with reading
    // `default_masks` directly — the drift the plan API exists to prevent.
    let (m, _be, plan) = loadgen::synth_fixture("qgemm", "named", Some(1), 5).unwrap();
    let resolved = QuantSource::NamedRatio("named".into())
        .resolve(&m)
        .unwrap()
        .expect("named source resolves to a plan");
    assert_eq!(resolved.masks, *m.default_masks.get("named").unwrap());
    assert_eq!(resolved.masks, plan.masks);

    let err = QuantSource::NamedRatio("absent".into()).resolve(&m).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("absent") && msg.contains("named"),
        "unknown plan error must list what exists: {msg}"
    );
}

#[test]
fn validate_rejects_mismatches_and_overlap() {
    let (m, _params, good) = loadgen::synth_plan("val", Ratio::new(65.0, 30.0, 5.0), 3);

    let mut p = good.clone();
    p.masks.layers[1].layer = "wrong/name".into();
    assert!(p.validate(&m).is_err(), "wrong layer name must be rejected");

    let mut p = good.clone();
    p.masks.layers[0].is8.truncate(1);
    p.masks.layers[0].is_pot.truncate(1);
    assert!(p.validate(&m).is_err(), "wrong row count must be rejected");

    let mut p = good.clone();
    p.masks.layers[0].is8[0] = 1.0;
    p.masks.layers[0].is_pot[0] = 1.0;
    let err = p.validate(&m).unwrap_err();
    assert!(
        format!("{err:#}").contains("exclusive"),
        "overlapping is8+is_pot must be rejected: {err:#}"
    );

    // A tampered plan file fails on load (non-binary value) or validate
    // (overlap) — either way before execution.
    let path = tmp_plan_path("tamper");
    good.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replacen("\"is8\":[", "\"is8\":[0.25,", 1)).unwrap();
    let result = QuantPlan::load(&path);
    assert!(result.is_err(), "tampered mask values must fail to load");
    std::fs::remove_file(&path).ok();
}
