//! End-to-end smoke tests for multi-model serving (`coordinator::pool` +
//! the pool routes of `coordinator::http`), on the artifact-free synthetic
//! fixtures — so the whole path runs in the `--no-default-features` CI leg.
//!
//! Pinned here (the acceptance contract for `ilmpq serve --pool`):
//!
//! * two models behind one listener have **isolated** pipelines: faulting
//!   one model leaves the other's failed/shed counters at zero;
//! * **live plan hot-swap** under sustained load loses zero replies, and
//!   post-swap logits are bit-for-bit what a cold start on the uploaded
//!   plan produces;
//! * an invalid plan upload is a `400` and the old plan keeps serving;
//! * entries prepare **lazily, exactly once**, even under concurrent first
//!   requests;
//! * an unknown model name is a `404` that lists the served models;
//! * per-model routes speak both wire encodings (JSON and raw
//!   little-endian f32), bit-identically, sizing raw bodies against the
//!   entry's own geometry.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ilmpq::backend::{self, synth, BackendInit, InferenceBackend};
use ilmpq::coordinator::pool::{synth_parts, ServerPool};
use ilmpq::coordinator::{HttpClient, HttpConfig, HttpServer, HttpTarget, RAW_CONTENT_TYPE};
use ilmpq::quant::{MaskSet, Provenance, QuantPlan, Ratio};
use ilmpq::util::{Json, Rng};

fn start_pool_front(pool: ServerPool) -> HttpServer {
    HttpServer::start_pool(
        Arc::new(pool),
        HttpConfig { addr: "127.0.0.1:0".into(), workers: 8, ..Default::default() },
    )
    .unwrap()
}

fn client_for(front: &HttpServer) -> HttpClient {
    let target = HttpTarget::parse(&format!("http://{}", front.local_addr())).unwrap();
    HttpClient::connect(&target, Duration::from_secs(30))
}

fn infer_body(image: &[f32]) -> String {
    Json::obj(vec![(
        "image",
        Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
    )])
    .to_string_compact()
}

fn normal_image(img: usize, rng: &mut Rng) -> Vec<f32> {
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    image
}

/// The raw wire encoding: the image verbatim as little-endian f32 bytes.
fn raw_body(image: &[f32]) -> Vec<u8> {
    let mut b = Vec::with_capacity(image.len() * 4);
    for v in image {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b
}

fn wire_logits(body: &str) -> Vec<f32> {
    Json::parse(body)
        .unwrap()
        .get("logits")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("no logits in {body}"))
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

/// A synthetic plan for the `tiny` entry of [`ServerPool::synthetic_pair`]
/// at a ratio visibly different from its initial `ilmpq2` plan. Mask draws
/// use their own RNG; bit-identity only needs the *params* stream, which
/// `synth_parts` reproduces.
fn swap_plan_for_tiny(pool_seed: u64) -> QuantPlan {
    let (m, _params) = synth_parts("tinyresnet", pool_seed).unwrap();
    let mut rng = Rng::new(4242);
    let masks = synth::random_masks(&m, Ratio::new(30.0, 60.0, 10.0), &mut rng);
    QuantPlan::from_mask_set(
        MaskSet { name: "swap-30-60-10".into(), layers: masks.layers },
        Provenance::Synthetic { seed: 4242, ratio: "30:60:10".into() },
    )
    .with_model(&m.model_name)
}

/// Faulting one model must not move another model's counters: each entry
/// has its own queue, workers, breaker, and `Metrics`.
#[test]
fn faulted_model_leaves_the_other_isolated() {
    let cfg = r#"{
        "default": "good",
        "models": [
            {"name": "good", "synthetic": "tinyresnet", "ratio": "ilmpq2", "seed": 3},
            {"name": "bad", "synthetic": "vggnarrow", "ratio": "65:30:5", "seed": 4,
             "fault": "chaos", "execute-deadline-ms": 100}
        ]
    }"#;
    let pool = ServerPool::from_json(&Json::parse(cfg).unwrap()).unwrap();
    let front = start_pool_front(pool);
    let mut client = client_for(&front);

    let listing = {
        let (code, body) = client.request("GET", "/v1/models", None).unwrap();
        assert_eq!(code, 200, "{body}");
        Json::parse(&body).unwrap()
    };
    assert_eq!(listing.get("default").and_then(Json::as_str), Some("good"));
    let names: Vec<String> = listing
        .get("models")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|r| r.get("name").and_then(Json::as_str).unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["good".to_string(), "bad".to_string()]);

    let good_img = {
        let (code, body) = client.request("GET", "/v1/models/good/healthz", None).unwrap();
        assert_eq!(code, 200, "{body}");
        Json::parse(&body).unwrap().get("image_elems").and_then(Json::as_usize).unwrap()
    };
    let bad_img = {
        let (code, body) = client.request("GET", "/v1/models/bad/healthz", None).unwrap();
        assert_eq!(code, 200, "{body}");
        Json::parse(&body).unwrap().get("image_elems").and_then(Json::as_usize).unwrap()
    };
    assert_ne!(good_img, bad_img, "the two geometries must differ");

    let mut rng = Rng::new(77);
    const GOOD_REQS: usize = 30;
    for i in 0..GOOD_REQS {
        let image = normal_image(good_img, &mut rng);
        let (code, body) =
            client.request("POST", "/v1/models/good/infer", Some(&infer_body(&image))).unwrap();
        assert_eq!(code, 200, "good request {i}: {body}");
        // Chaos on `bad` between every good request; any status is fine —
        // the schedule is probabilistic — it only must not bleed over.
        let image = normal_image(bad_img, &mut rng);
        let (code, _) =
            client.request("POST", "/v1/models/bad/infer", Some(&infer_body(&image))).unwrap();
        assert!(code == 200 || code >= 400, "bad model returned {code}");
    }

    let (code, body) = client.request("GET", "/v1/models/good/metrics", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let m = Json::parse(&body).unwrap();
    let get = |k: &str| m.get(k).and_then(Json::as_f64).unwrap();
    assert_eq!(get("requests_done"), GOOD_REQS as f64, "{body}");
    assert_eq!(get("requests_failed"), 0.0, "fault bled into the clean model: {body}");
    assert_eq!(get("requests_shed"), 0.0, "fault bled into the clean model: {body}");

    front.stop();
}

/// The headline: swap the `tiny` model's plan while a client hammers it.
/// Every reply must arrive (no 500/503/504 — zero lost), the advertised
/// plan must flip, and post-swap logits must be bit-identical to a cold
/// start on the uploaded plan. An invalid upload afterwards is a 400 and
/// the swapped plan keeps serving.
#[test]
fn hot_swap_under_load_loses_nothing_and_matches_cold_start() {
    const SEED: u64 = 11;
    let pool = ServerPool::synthetic_pair(SEED).unwrap();
    let front = start_pool_front(pool);
    let addr = front.local_addr();
    let plan2 = swap_plan_for_tiny(SEED);

    let img = {
        let mut client = client_for(&front);
        let (code, body) = client.request("GET", "/v1/models/tiny/healthz", None).unwrap();
        assert_eq!(code, 200, "{body}");
        Json::parse(&body).unwrap().get("image_elems").and_then(Json::as_usize).unwrap()
    };

    let stop = Arc::new(AtomicBool::new(false));
    let hammers: Vec<_> = (0..2u64)
        .map(|t| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let target = HttpTarget::parse(&format!("http://{addr}")).unwrap();
                let mut client = HttpClient::connect(&target, Duration::from_secs(30));
                let mut rng = Rng::new(500 + t);
                let mut codes: Vec<u16> = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    let image = normal_image(img, &mut rng);
                    let (code, _) = client
                        .request("POST", "/v1/models/tiny/infer", Some(&infer_body(&image)))
                        .unwrap();
                    codes.push(code);
                }
                codes
            })
        })
        .collect();

    // Let the hammers warm up (this also exercises the lazy first build),
    // then swing the plan mid-stream.
    std::thread::sleep(Duration::from_millis(150));
    let mut client = client_for(&front);
    let (code, body) = client
        .request("POST", "/v1/models/tiny/plan", Some(&plan2.to_json().to_string_compact()))
        .unwrap();
    assert_eq!(code, 200, "swap rejected: {body}");
    let j = Json::parse(&body).unwrap();
    assert!(matches!(j.get("swapped"), Some(Json::Bool(true))), "{body}");
    assert_eq!(j.get("plan").and_then(Json::as_str), Some("swap-30-60-10"), "{body}");

    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    let mut total = 0usize;
    for h in hammers {
        let codes = h.join().unwrap();
        assert!(!codes.is_empty(), "hammer never got a reply");
        for (i, code) in codes.iter().enumerate() {
            assert_eq!(*code, 200, "reply {i} of {} lost across the swap", codes.len());
        }
        total += codes.len();
    }
    assert!(total > 0);

    // The advertised plan is the uploaded one...
    let (code, body) = client.request("GET", "/v1/models/tiny/plan", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("name").and_then(Json::as_str), Some("swap-30-60-10"), "{body}");

    // ...and serving on it is bit-identical to a cold start on it: same
    // params (synth_parts reproduces the entry's draw), same plan, fresh
    // backend.
    let image = normal_image(img, &mut Rng::new(9));
    let (code, body) =
        client.request("POST", "/v1/models/tiny/infer", Some(&infer_body(&image))).unwrap();
    assert_eq!(code, 200, "{body}");
    let got = wire_logits(&body);
    let (m, params) = synth_parts("tinyresnet", SEED).unwrap();
    let init = BackendInit {
        plan: Some(plan2.clone()),
        threads: None,
        frozen: true,
        ..BackendInit::new(m, params)
    };
    let reference = backend::create("qgemm", &init).unwrap();
    let expect = reference.run_batch(&image, 1).unwrap().logits;
    assert_eq!(got.len(), expect.len());
    for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
        assert!(g == e, "logit {i} drifted after the swap: {g} != {e}");
    }

    // Garbage upload: 400, and the swapped plan keeps serving.
    let (code, body) =
        client.request("POST", "/v1/models/tiny/plan", Some("{\"x\":1}")).unwrap();
    assert_eq!(code, 400, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("invalid_plan"), "{body}");
    let (code, body) = client.request("GET", "/v1/models/tiny/plan", None).unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("name").and_then(Json::as_str), Some("swap-30-60-10"), "{body}");
    let (code, _) =
        client.request("POST", "/v1/models/tiny/infer", Some(&infer_body(&image))).unwrap();
    assert_eq!(code, 200, "model stopped serving after a rejected upload");

    front.stop();
}

/// Concurrent first requests build the backend exactly once, and an
/// untouched entry never builds at all.
#[test]
fn entries_prepare_lazily_and_exactly_once() {
    let pool = ServerPool::synthetic_pair(21).unwrap();
    let tiny = pool.entry("tiny").unwrap().clone();
    let narrow = pool.entry("narrow").unwrap().clone();
    assert_eq!(tiny.prepares(), 0, "cold entry must not have built");
    assert_eq!(narrow.prepares(), 0);

    let handles: Vec<_> = (0..8)
        .map(|_| {
            let e = tiny.clone();
            std::thread::spawn(move || {
                let rx = e.submit(vec![0.2f32; e.image_elems()]).unwrap();
                rx.recv_timeout(Duration::from_secs(30)).unwrap()
            })
        })
        .collect();
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.is_ok(), "{reply:?}");
    }
    assert_eq!(tiny.prepares(), 1, "concurrent first requests built more than once");
    assert_eq!(narrow.prepares(), 0, "untouched entry built eagerly");

    let metrics = pool.shutdown();
    assert_eq!(metrics.audit(), Ok(()), "default entry ledger must balance at shutdown");
}

/// Per-model routes speak both wire encodings: a raw little-endian f32
/// body posted to `/v1/models/{name}/infer` produces logits bit-identical
/// to the JSON route, and the expected raw size is the *entry's* geometry
/// — a body sized for the other model bounces with `bad_tensor_size`.
#[test]
fn per_model_routes_accept_raw_bodies_bit_identical_with_json() {
    let pool = ServerPool::synthetic_pair(31).unwrap();
    let front = start_pool_front(pool);
    let mut client = client_for(&front);
    let mut rng = Rng::new(63);

    let mut geometries = Vec::new();
    for model in ["tiny", "narrow"] {
        let img = {
            let (code, body) =
                client.request("GET", &format!("/v1/models/{model}/healthz"), None).unwrap();
            assert_eq!(code, 200, "{body}");
            Json::parse(&body).unwrap().get("image_elems").and_then(Json::as_usize).unwrap()
        };
        geometries.push(img);
        let image = normal_image(img, &mut rng);
        let path = format!("/v1/models/{model}/infer");
        let (code, body) = client
            .request_bytes("POST", &path, &raw_body(&image), RAW_CONTENT_TYPE)
            .unwrap();
        assert_eq!(code, 200, "{model} raw: {body}");
        let raw_logits = wire_logits(&body);
        let (code, body) = client.request("POST", &path, Some(&infer_body(&image))).unwrap();
        assert_eq!(code, 200, "{model} json: {body}");
        assert_eq!(
            wire_logits(&body),
            raw_logits,
            "{model}: JSON and raw routes must agree bit-for-bit"
        );
    }

    // A raw body sized for `tiny` posted to `narrow` (different geometry)
    // must bounce against *narrow's* expected size.
    let (tiny_img, narrow_img) = (geometries[0], geometries[1]);
    assert_ne!(tiny_img, narrow_img, "the pair's geometries must differ");
    let wrong = raw_body(&vec![0.5f32; tiny_img]);
    let (code, body) = client
        .request_bytes("POST", "/v1/models/narrow/infer", &wrong, RAW_CONTENT_TYPE)
        .unwrap();
    assert_eq!(code, 400, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("bad_tensor_size"), "{body}");
    assert!(
        j.get("error").and_then(Json::as_str).unwrap().contains(&narrow_img.to_string()),
        "the 400 must name the route's own expected element count: {body}"
    );

    front.stop();
}

/// Routing to a model the pool does not serve is a 404 that names the
/// models it does.
#[test]
fn unknown_model_is_a_404_listing_the_pool() {
    let pool = ServerPool::synthetic_pair(5).unwrap();
    let front = start_pool_front(pool);
    let mut client = client_for(&front);

    for (method, path) in [
        ("GET", "/v1/models/nope"),
        ("POST", "/v1/models/nope/infer"),
        ("GET", "/v1/models/nope/plan"),
    ] {
        let body_arg = if method == "POST" { Some("{\"image\": []}") } else { None };
        let (code, body) = client.request(method, path, body_arg).unwrap();
        assert_eq!(code, 404, "{method} {path}: {body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("unknown_model"), "{body}");
        let models: Vec<&str> = j
            .get("models")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        assert_eq!(models, vec!["tiny", "narrow"], "{body}");
    }

    front.stop();
}
