//! Integration: the native packed-GEMM backend agrees with the PJRT frozen
//! path on the real AOT model + test split — all three execution paths
//! driven through the unified `backend::InferenceBackend` API.
//!
//! Requires `make artifacts` (like `e2e_runtime.rs`); when the artifacts dir
//! is missing these tests skip with a note instead of failing, so the
//! pure-CPU suite stays runnable everywhere.

use std::sync::Arc;

use ilmpq::backend::{FloatRefBackend, InferenceBackend, PjrtBackend, QgemmBackend};
use ilmpq::experiments::ptq;
use ilmpq::quant::freeze;
use ilmpq::runtime::Runtime;

mod common;

fn runtime_or_skip() -> Option<Runtime> {
    common::runtime_or_skip("qgemm integration")
}

/// Fraction of positions where the two prediction vectors agree.
fn agreement(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len(), "prediction count mismatch");
    assert!(!a.is_empty());
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

#[test]
fn backends_agree_on_trained_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let rt = Arc::new(rt);
    // A short reference train gives well-separated logits; untrained
    // near-chance logits would make argmax comparisons meaningless.
    let params = ptq::train_reference(&rt, 150, 2021, |_| {}).unwrap();
    let m = rt.manifest.clone();
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    let masks = m.plan("ilmpq2").unwrap().masks;
    let frozen = freeze::freeze_params(&params, &names, &masks);

    // Float Rust backend vs PJRT: identical math modulo f32 association —
    // argmax must agree essentially everywhere.
    let pjrt_be = PjrtBackend::frozen_as_given(rt.clone(), frozen.clone());
    let pjrt = ptq::predict_with(&pjrt_be, &m).unwrap();
    let float_be = FloatRefBackend::new(m.clone(), frozen.clone());
    let float_rs = ptq::predict_with(&float_be, &m).unwrap();
    let float_agree = agreement(&pjrt, &float_rs);
    assert!(
        float_agree >= 0.995,
        "float Rust backend diverged from PJRT: agreement {float_agree:.4}"
    );

    // Packed integer backend: adds only 8-bit activation noise on top of
    // the same frozen weights — argmax must agree on (nearly) every sample
    // and the accuracies must match closely. One backend instance packs
    // once and serves both the prediction and the accuracy pass.
    let packed_be = QgemmBackend::new(m.clone(), frozen.clone(), masks.clone());
    packed_be.prepare().unwrap();
    let packed = ptq::predict_with(&packed_be, &m).unwrap();
    let packed_agree = agreement(&pjrt, &packed);
    assert!(
        packed_agree >= 0.98,
        "packed qgemm backend diverged from PJRT: agreement {packed_agree:.4}"
    );

    let acc_pjrt = ptq::eval_with(&pjrt_be, &m).unwrap();
    let acc_qgemm = ptq::eval_with(&packed_be, &m).unwrap();
    assert!(
        (acc_pjrt - acc_qgemm).abs() < 0.01,
        "accuracy drifted: pjrt {acc_pjrt:.4} vs qgemm {acc_qgemm:.4}"
    );
}

#[test]
fn qgemm_eval_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest.clone();
    let params = m.load_init_params().unwrap();
    let masks = m.plan("ilmpq1").unwrap().masks;
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    let frozen = freeze::freeze_params(&params, &names, &masks);
    // Same backend instance twice (cached pack), and a fresh instance: all
    // three prediction vectors must be identical.
    let be = QgemmBackend::new(m.clone(), frozen.clone(), masks.clone());
    let a = ptq::predict_with(&be, &m).unwrap();
    let b = ptq::predict_with(&be, &m).unwrap();
    assert_eq!(a, b, "packed eval must be deterministic across the cached pack");
    let be2 = QgemmBackend::new(m.clone(), frozen, masks);
    let c = ptq::predict_with(&be2, &m).unwrap();
    assert_eq!(a, c, "packed eval must be deterministic across instances");
}
