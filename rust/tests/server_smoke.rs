//! Server smoke + admission-pipeline tests on the `qgemm` backend —
//! artifact-free and PJRT-free, so the full serving loop (admission
//! validation, bounded queue, router, dynamic batcher, worker pool,
//! FPGA-sim latency overlay, typed-error replies) is exercised by the
//! `--no-default-features` CI leg on every push.
//!
//! The acceptance checks for the admission pipeline live here:
//!
//! * a malformed request (wrong length / non-finite) is rejected alone
//!   with `ServeError::InvalidInput` while its would-be batch-mates still
//!   receive **bit-correct** logits — the pre-pipeline behaviour let a
//!   short image shift every later image's offset in the batch buffer;
//! * an unpaced burst beyond `queue_depth` sheds with `QueueFull` while
//!   accepted requests complete;
//! * `stop()` answers every in-flight request (executed or
//!   `ShuttingDown`) instead of dropping reply channels;
//! * a failing backend answers every member of the failed batch with
//!   `BackendFailed`, and the failure never pollutes the `execute`
//!   latency percentiles;
//! * the one-owned-buffer invariant: each accepted image crosses the
//!   backend in exactly one batch row, bit-exact with what was submitted
//!   (see ROADMAP "Architecture: wire encodings & ingestion").

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ilmpq::backend::{self, synth, BackendInit, BatchOutput, InferenceBackend};
use ilmpq::coordinator::{Metrics, ServeConfig, ServeError, Server};
use ilmpq::quant::{MaskSet, Provenance, QuantPlan, Ratio};
use ilmpq::util::Rng;

const H: usize = 8;
const W: usize = 8;
const C: usize = 3;
const CLASSES: usize = 5;

/// Synthetic manifest + a qgemm backend over it, plus the quantization
/// plan (for `ServeConfig::plan`, which drives the FPGA-sim overlay).
fn fixture(
    plan_name: &str,
) -> (ilmpq::runtime::Manifest, Arc<dyn InferenceBackend>, QuantPlan, Rng) {
    let mut rng = Rng::new(11);
    let m = synth::tiny_manifest(H, W, C, &[4, 8], CLASSES);
    let params = synth::random_params(&m, &mut rng);
    let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
    let plan = QuantPlan::from_mask_set(
        MaskSet { name: plan_name.to_string(), layers: masks.layers },
        Provenance::Synthetic { seed: 11, ratio: "65:30:5".into() },
    );
    let init = BackendInit {
        plan: Some(plan.clone()),
        threads: Some(2),
        ..BackendInit::new(m.clone(), params)
    };
    let be: Arc<dyn InferenceBackend> =
        Arc::from(backend::create("qgemm", &init).unwrap());
    (m, be, plan, rng)
}

fn normal_image(img: usize, rng: &mut Rng) -> Vec<f32> {
    let mut image = vec![0f32; img];
    rng.fill_normal(&mut image, 1.0);
    image
}

#[test]
fn serving_end_to_end_on_qgemm_without_artifacts() {
    let (m, be, plan, mut rng) = fixture("smoke");
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(2),
        plan: Some(plan),
        device: "xc7z045".into(),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    assert!(server.sim.latency_s > 0.0, "FPGA-sim overlay must resolve");

    let img = m.data.image_elems();
    let n = 24;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(normal_image(img, &mut rng))).collect();
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("response")
            .expect("well-formed request must succeed");
        assert_eq!(resp.logits.len(), CLASSES);
        assert!(resp.pred < CLASSES);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.sim_fpga > Duration::ZERO, "sim overlay attached per request");
        // queue_wait is measured from *submit* time (same anchor as e2e),
        // so this holds by construction; a regression to the old
        // router-push anchor would let submit-channel congestion break it.
        assert!(resp.queue_wait <= resp.e2e, "queue_wait must bound below e2e");
        assert!(resp.queue_wait > Duration::ZERO, "submit-to-execute cannot be instant");
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_done), n as u64);
    assert_eq!(Metrics::get(&metrics.requests_invalid), 0);
    assert_eq!(Metrics::get(&metrics.requests_shed), 0);
    assert_eq!(Metrics::get(&metrics.requests_failed), 0);
    assert!(metrics.batch_occupancy() > 0.0);
    assert!(metrics.execute.count() > 0 && metrics.sim_fpga.count() > 0);
    assert_eq!(metrics.failed.count(), 0);
}

#[test]
fn malformed_request_rejected_alone_neighbors_bit_correct() {
    let (m, be, plan, mut rng) = fixture("adm");
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(2),
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be.clone(), cfg).unwrap();
    let sim_per_image = server.sim.latency_s;
    let img = m.data.image_elems();
    let n = 16;
    let images: Vec<Vec<f32>> = (0..n).map(|_| normal_image(img, &mut rng)).collect();
    // Reference logits for every image through the same backend, batch 1.
    // The packed forward is per-row deterministic, so a request's logits
    // must be bit-identical no matter which batch the server put it in —
    // unless a malformed neighbour shifted its offset.
    let reference: Vec<BatchOutput> =
        images.iter().map(|x| be.run_batch(x, 1).unwrap()).collect();

    let mut rxs = Vec::new();
    let mut bad = Vec::new();
    for (i, image) in images.iter().enumerate() {
        rxs.push(server.submit(image.clone()));
        if i == n / 3 {
            // Mid-stream malformed requests: short, long, and non-finite.
            bad.push(server.submit(vec![0.0; img / 2]));
            bad.push(server.submit(vec![0.0; img + 3]));
            let mut nan = image.clone();
            nan[5] = f32::NAN;
            bad.push(server.submit(nan));
        }
    }
    for rx in bad {
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("typed reply");
        assert!(
            matches!(resp, Err(ServeError::InvalidInput(_))),
            "malformed request must be rejected alone: {resp:?}"
        );
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("reply")
            .expect("well-formed neighbours must succeed");
        assert_eq!(resp.pred, reference[i].preds[0], "request {i}: argmax corrupted");
        assert!(
            resp.logits
                .iter()
                .zip(&reference[i].logits)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "request {i}: neighbour logits not bit-correct"
        );
        // Per-request FPGA-sim attribution: one image's latency, not the
        // whole batch's (Duration round-trips through ns resolution).
        assert!(
            (resp.sim_fpga.as_secs_f64() - sim_per_image).abs() < 2e-9,
            "sim_fpga {} vs per-image {}",
            resp.sim_fpga.as_secs_f64(),
            sim_per_image
        );
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_done), n as u64);
    assert_eq!(Metrics::get(&metrics.requests_invalid), 3);
    assert_eq!(Metrics::get(&metrics.batches_failed), 0);
}

/// Wraps a real backend and records every batch row it is handed — the
/// probe for the one-owned-buffer invariant: each image is written into
/// the batch buffer exactly once (its decode into the `ImageBuf` plus one
/// placement), so each must surface as exactly one bit-exact row.
struct CountingBackend {
    inner: Arc<dyn InferenceBackend>,
    seen: Mutex<Vec<Vec<f32>>>,
}

impl InferenceBackend for CountingBackend {
    fn name(&self) -> &str {
        "counting"
    }

    fn supports_frozen(&self) -> bool {
        self.inner.supports_frozen()
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> anyhow::Result<BatchOutput> {
        let img = images.len() / batch.max(1);
        let mut seen = self.seen.lock().unwrap();
        for row in images.chunks_exact(img) {
            seen.push(row.to_vec());
        }
        drop(seen);
        self.inner.run_batch(images, batch)
    }
}

#[test]
fn batch_buffer_carries_each_image_in_exactly_one_row() {
    let (m, inner, plan, mut rng) = fixture("cnt");
    let counting = Arc::new(CountingBackend { inner, seen: Mutex::new(Vec::new()) });
    let be: Arc<dyn InferenceBackend> = counting.clone();
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(2),
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    let img = m.data.image_elems();
    let n = 24usize;
    let images: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = normal_image(img, &mut rng);
            // Distinct sentinel per image, so rows are attributable.
            v[0] = i as f32 + 0.5;
            v
        })
        .collect();
    let rxs: Vec<_> = images.iter().map(|x| server.submit(x.clone())).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30))
            .expect("reply")
            .expect("well-formed request must succeed");
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    let seen = counting.seen.lock().unwrap();
    // Every accepted image crossed the backend exactly once in total —
    // no image duplicated into two batches, none dropped, none re-run.
    assert_eq!(seen.len(), n, "backend must see exactly one row per image");
    for (i, image) in images.iter().enumerate() {
        let hits: Vec<_> = seen.iter().filter(|row| row[0] == image[0]).collect();
        assert_eq!(hits.len(), 1, "image {i} must occupy exactly one batch row");
        assert!(
            hits[0].iter().zip(image).all(|(a, b)| a.to_bits() == b.to_bits()),
            "image {i}: batch row not bit-exact with the submitted buffer"
        );
    }
}

#[test]
fn overload_sheds_with_queue_full_while_accepted_complete() {
    let (m, be, plan, mut rng) = fixture("ovl");
    let depth = 4usize;
    let cfg = ServeConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: depth,
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    let img = m.data.image_elems();
    let n = 256;
    // Unpaced burst: submission is orders of magnitude faster than the
    // backend, so the in-system bound must trip.
    let rxs: Vec<_> = (0..n).map(|_| server.submit(normal_image(img, &mut rng))).collect();
    let (mut done, mut shed) = (0u64, 0u64);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("typed reply") {
            Ok(resp) => {
                assert_eq!(resp.logits.len(), CLASSES);
                done += 1;
            }
            Err(ServeError::QueueFull { depth: d }) => {
                assert_eq!(d, depth);
                shed += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(done + shed, n as u64);
    assert!(done >= depth as u64, "the first depth-worth must complete, got {done}");
    assert!(shed > 0, "an unpaced burst of {n} must shed at depth {depth}");
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_done), done);
    assert_eq!(Metrics::get(&metrics.requests_shed), shed);
    assert!(metrics.shed_rate() > 0.0);
}

#[test]
fn stop_answers_every_in_flight_request() {
    let (m, be, plan, mut rng) = fixture("stp");
    let cfg = ServeConfig {
        workers: 2,
        // Long deadline: stop() hits while requests still sit in the
        // batcher, exercising the flush + ShuttingDown drain.
        max_wait: Duration::from_millis(50),
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    let img = m.data.image_elems();
    let n = 32;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(normal_image(img, &mut rng))).collect();
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    let (mut ok, mut shutdown) = (0u64, 0u64);
    for rx in rxs {
        match rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every in-flight request must be answered, not dropped")
        {
            Ok(_) => ok += 1,
            Err(ServeError::ShuttingDown) => shutdown += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(ok + shutdown, n as u64);
    assert_eq!(
        Metrics::get(&metrics.requests_done) + Metrics::get(&metrics.requests_shutdown),
        n as u64
    );
}

/// A backend whose every batch errors — exercises the failed-batch path.
struct FailingBackend;

impl InferenceBackend for FailingBackend {
    fn name(&self) -> &str {
        "failing"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn run_batch(&self, _images: &[f32], _batch: usize) -> anyhow::Result<BatchOutput> {
        anyhow::bail!("injected backend failure")
    }
}

/// A backend that panics — the worker must contain it, answer every caller,
/// and not leak admission slots.
struct PanickingBackend;

impl InferenceBackend for PanickingBackend {
    fn name(&self) -> &str {
        "panicking"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn run_batch(&self, _images: &[f32], _batch: usize) -> anyhow::Result<BatchOutput> {
        panic!("injected backend panic")
    }
}

/// A backend returning a degenerate self-consistent output (0 classes,
/// empty logits) — must be caught by the manifest-side shape validation.
struct DegenerateBackend;

impl InferenceBackend for DegenerateBackend {
    fn name(&self) -> &str {
        "degenerate"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn run_batch(&self, _images: &[f32], batch: usize) -> anyhow::Result<BatchOutput> {
        Ok(BatchOutput {
            logits: Vec::new(),
            preds: vec![0; batch],
            classes: 0,
            elapsed: Duration::ZERO,
        })
    }
}

#[test]
fn failed_batches_answer_every_caller_with_typed_error() {
    let (m, _be, plan, mut rng) = fixture("fail");
    let be: Arc<dyn InferenceBackend> = Arc::new(FailingBackend);
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(1),
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    let img = m.data.image_elems();
    let n = 12;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(normal_image(img, &mut rng))).collect();
    for rx in rxs {
        match rx
            .recv_timeout(Duration::from_secs(10))
            .expect("failed batch must answer, not drop channels")
        {
            Err(ServeError::BackendFailed(msg)) => {
                assert!(msg.contains("injected"), "{msg}");
            }
            other => panic!("expected BackendFailed, got {other:?}"),
        }
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_failed), n as u64);
    assert!(Metrics::get(&metrics.batches_failed) >= 1);
    // Failures must not pollute the execute percentiles: they land in the
    // dedicated `failed` track.
    assert_eq!(metrics.execute.count(), 0);
    assert!(metrics.failed.count() >= 1);
    assert_eq!(Metrics::get(&metrics.requests_done), 0);
}

/// Shared harness for the containment backends: every caller must get a
/// typed `BackendFailed` whose reason contains `expect_msg`, with no leaked
/// admission slots (a fresh round after the failures still gets answers).
fn assert_contained(be: Arc<dyn InferenceBackend>, plan_name: &str, expect_msg: &str) {
    let (m, _unused, plan, mut rng) = fixture(plan_name);
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(1),
        // Tight bound: a single leaked batch of slots would wedge round 2
        // into permanent QueueFull.
        queue_depth: 4,
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();
    let img = m.data.image_elems();
    for round in 0..2 {
        let rxs: Vec<_> =
            (0..4).map(|_| server.submit(normal_image(img, &mut rng))).collect();
        let mut failed = 0;
        for rx in rxs {
            match rx
                .recv_timeout(Duration::from_secs(10))
                .expect("contained failure must answer, not drop or wedge")
            {
                Err(ServeError::BackendFailed(msg)) => {
                    assert!(msg.contains(expect_msg), "round {round}: {msg}");
                    failed += 1;
                }
                Err(ServeError::QueueFull { .. }) => {
                    panic!("round {round}: admission slots leaked into QueueFull")
                }
                other => panic!("round {round}: expected BackendFailed, got {other:?}"),
            }
        }
        assert!(failed > 0, "round {round} produced no typed failures");
    }
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    assert_eq!(Metrics::get(&metrics.requests_done), 0);
    assert!(Metrics::get(&metrics.batches_failed) >= 2);
}

#[test]
fn backend_panic_is_contained_without_leaking_admission_slots() {
    assert_contained(Arc::new(PanickingBackend), "pnc", "injected backend panic");
}

#[test]
fn degenerate_backend_output_is_rejected_not_served() {
    assert_contained(Arc::new(DegenerateBackend), "dgn", "malformed output");
}

#[test]
fn idle_router_parks_and_batch_deadline_still_fires() {
    let (m, be, plan, mut rng) = fixture("idle");
    let max_wait = Duration::from_millis(40);
    let cfg = ServeConfig {
        workers: 1,
        max_wait,
        plan: Some(plan),
        ..Default::default()
    };
    let server = Server::start(&m, be, cfg).unwrap();

    // Idle phase: with an empty queue the router must *block* on the
    // submit channel, not poll it. The historic capped-sleep loop woke
    // every <=500µs (hundreds of iterations in this window); the parked
    // router registers only its startup iterations.
    std::thread::sleep(Duration::from_millis(300));
    let idle_wakeups = Metrics::get(&server.metrics.router_wakeups);
    assert_eq!(
        Metrics::get(&server.metrics.batches),
        0,
        "idle router must not dispatch"
    );
    assert!(
        idle_wakeups <= 10,
        "idle router must park, not busy-poll: {idle_wakeups} wakeups in 300ms \
         (the old loop produced ~600+)"
    );

    // Deadline phase: parking must not break the batcher's latency SLO. A
    // lone request (below the full-batch size) ships when the oldest
    // request has waited `max_wait` — the recv_timeout bound — not never.
    let img = m.data.image_elems();
    let t0 = std::time::Instant::now();
    let rx = server.submit(normal_image(img, &mut rng));
    let resp = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("deadline dispatch must fire on a parked router")
        .expect("well-formed request must succeed");
    let waited = t0.elapsed();
    assert!(
        waited >= max_wait / 2,
        "a lone request dispatches at the batch deadline, not instantly: {waited:?}"
    );
    assert!(resp.queue_wait <= resp.e2e);
    let metrics = server.stop();
    assert_eq!(metrics.audit(), Ok(()), "metrics ledger must balance at stop");
    // Submit + deadline + stop account for a handful of iterations.
    let total = Metrics::get(&metrics.router_wakeups);
    assert!(total <= 20, "router wakeups stayed bounded: {total}");
    assert_eq!(Metrics::get(&metrics.requests_done), 1);
}

#[test]
fn server_validates_plan_and_device_for_any_backend() {
    let (m, be, plan, _) = fixture("smoke");

    // A plan that doesn't fit the manifest (corrupted row count) must be
    // rejected at startup, before it can drive the sim overlay or a pack.
    let mut corrupt = plan.clone();
    corrupt.masks.layers[0].is8.push(0.0);
    corrupt.masks.layers[0].is_pot.push(0.0);
    let err = Server::start(
        &m,
        be.clone(),
        ServeConfig { plan: Some(corrupt), ..Default::default() },
    )
    .err()
    .expect("mismatched plan must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("plan") && msg.contains("rows"), "{msg}");

    let err = Server::start(
        &m,
        be,
        ServeConfig {
            plan: Some(plan),
            device: "xc7z999".into(),
            ..Default::default()
        },
    )
    .err()
    .expect("unknown device must fail");
    assert!(format!("{err:#}").contains("unknown device"));
}
