//! Server smoke test on the `qgemm` backend — artifact-free and
//! PJRT-free, so the full serving loop (router, dynamic batcher, worker
//! pool, FPGA-sim latency overlay) is exercised by the
//! `--no-default-features` CI leg on every push.
//!
//! This is the acceptance check for the backend-generic server: the same
//! `coordinator::server` that fronted PJRT now runs end-to-end over the
//! packed-code integer path, on a machine with nothing but a Rust
//! toolchain.

use std::sync::Arc;
use std::time::Duration;

use ilmpq::backend::{self, synth, BackendInit, InferenceBackend};
use ilmpq::coordinator::{Metrics, ServeConfig, Server};
use ilmpq::quant::Ratio;
use ilmpq::util::Rng;

const H: usize = 8;
const W: usize = 8;
const C: usize = 3;
const CLASSES: usize = 5;

/// Synthetic manifest + a qgemm backend over it, with the mask set also
/// registered under `default_masks` so the FPGA-sim overlay resolves.
fn fixture(ratio_name: &str) -> (ilmpq::runtime::Manifest, Arc<dyn InferenceBackend>, Rng) {
    let mut rng = Rng::new(11);
    let mut m = synth::tiny_manifest(H, W, C, &[4, 8], CLASSES);
    let params = synth::random_params(&m, &mut rng);
    let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
    m.default_masks.insert(ratio_name.to_string(), masks.clone());
    let init = BackendInit {
        masks: Some(masks),
        threads: Some(2),
        ..BackendInit::new(m.clone(), params)
    };
    let be: Arc<dyn InferenceBackend> =
        Arc::from(backend::create("qgemm", &init).unwrap());
    (m, be, rng)
}

#[test]
fn serving_end_to_end_on_qgemm_without_artifacts() {
    let (m, be, mut rng) = fixture("smoke");
    let cfg = ServeConfig {
        workers: 2,
        max_wait: Duration::from_millis(2),
        ratio_name: "smoke".into(),
        device: "xc7z045".into(),
        frozen: true,
    };
    let server = Server::start(&m, be, cfg).unwrap();
    assert!(server.sim.latency_s > 0.0, "FPGA-sim overlay must resolve");

    let img = m.data.image_elems();
    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let mut image = vec![0f32; img];
            rng.fill_normal(&mut image, 1.0);
            server.submit(image)
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(resp.logits.len(), CLASSES);
        assert!(resp.pred < CLASSES);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.sim_fpga > Duration::ZERO, "sim overlay attached per batch");
        assert!(resp.e2e >= resp.queue_wait);
    }
    let metrics = server.stop();
    assert_eq!(Metrics::get(&metrics.requests_done), n as u64);
    assert_eq!(Metrics::get(&metrics.requests_rejected), 0);
    assert!(metrics.batch_occupancy() > 0.0);
    assert!(metrics.execute.count() > 0 && metrics.sim_fpga.count() > 0);
}

#[test]
fn server_validates_ratio_and_device_for_any_backend() {
    let (m, be, _) = fixture("smoke");
    let err = Server::start(
        &m,
        be.clone(),
        ServeConfig { ratio_name: "bogus".into(), ..Default::default() },
    )
    .err()
    .expect("unknown ratio must fail");
    assert!(format!("{err:#}").contains("unknown ratio"));

    let err = Server::start(
        &m,
        be,
        ServeConfig {
            ratio_name: "smoke".into(),
            device: "xc7z999".into(),
            ..Default::default()
        },
    )
    .err()
    .expect("unknown device must fail");
    assert!(format!("{err:#}").contains("unknown device"));
}
