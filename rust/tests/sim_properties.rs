//! Property tests over the FPGA performance simulator: physical sanity that
//! must hold for ANY configuration, not just the Table-I points. Pure
//! simulation — no artifacts needed.

use ilmpq::fpga::{simulate, DeviceModel, Mode, NetConfig};
use ilmpq::model::{resnet18, zoo};
use ilmpq::quant::Ratio;
use ilmpq::util::prop::{ensure, forall};
use ilmpq::util::Rng;

fn random_ratio(r: &mut Rng) -> Ratio {
    let f8 = (r.below(4) * 5) as f64; // 0, 5, 10, 15
    let pot = (r.f64() * (100.0 - f8) * 10.0).round() / 10.0;
    Ratio::new(pot, 100.0 - f8 - pot, f8)
}

#[test]
fn prop_latency_positive_and_throughput_consistent() {
    let net = resnet18();
    forall(
        201,
        64,
        |r| (random_ratio(r), r.bool(0.5), r.bool(0.5)),
        |&(ratio, fl8, big)| {
            let device = if big { DeviceModel::xc7z045() } else { DeviceModel::xc7z020() };
            let cfg = NetConfig::from_ratio(&net, ratio, fl8, "prop");
            let rep = simulate(&net, &cfg, &device, Mode::IntraLayer);
            ensure(rep.latency_s > 0.0, || "non-positive latency".into())?;
            ensure(rep.latency_s.is_finite(), || "infinite latency".into())?;
            let tp = net.total_gops() / rep.latency_s;
            ensure(
                (tp - rep.throughput_gops).abs() < 1e-9,
                || format!("throughput {} != gops/latency {tp}", rep.throughput_gops),
            )?;
            ensure(
                rep.lut_util <= 1.0 && rep.dsp_util <= 1.0,
                || format!("utilization out of range: {rep:?}"),
            )
        },
    );
}

#[test]
fn prop_bigger_device_never_slower() {
    let net = resnet18();
    forall(
        202,
        32,
        |r| (random_ratio(r), r.bool(0.5)),
        |&(ratio, fl8)| {
            let cfg = NetConfig::from_ratio(&net, ratio, fl8, "prop");
            let small = simulate(&net, &cfg, &DeviceModel::xc7z020(), Mode::IntraLayer);
            let big = simulate(&net, &cfg, &DeviceModel::xc7z045(), Mode::IntraLayer);
            ensure(
                big.latency_s <= small.latency_s * 1.001,
                || format!("Z045 slower: {} vs {}", big.latency_s, small.latency_s),
            )
        },
    );
}

#[test]
fn prop_per_layer_times_sum_to_latency() {
    let net = zoo::vgg11();
    forall(
        203,
        32,
        |r| random_ratio(r),
        |&ratio| {
            let cfg = NetConfig::from_ratio(&net, ratio, false, "prop");
            let rep = simulate(&net, &cfg, &DeviceModel::xc7z045(), Mode::IntraLayer);
            let sum: f64 = rep.per_layer.iter().map(|t| t.total_s).sum();
            ensure(
                (sum - rep.latency_s).abs() < 1e-9,
                || format!("sum {} != latency {}", sum, rep.latency_s),
            )?;
            ensure(rep.per_layer.len() == net.layers.len(), || "layer count".into())
        },
    );
}

#[test]
fn prop_inter_layer_never_beats_intra_on_fl8_configs() {
    // On layer-uniform (fl8) configs the idle-pool penalty must make the
    // inter-layer execution at best equal, never better.
    let net = resnet18();
    forall(
        204,
        24,
        |r| {
            let pot = (r.below(3) * 50) as f64; // 0, 50, 100
            Ratio::new(pot, 100.0 - pot, 0.0)
        },
        |&ratio| {
            let cfg = NetConfig::from_ratio(&net, ratio, true, "prop");
            let intra = simulate(&net, &cfg, &DeviceModel::xc7z045(), Mode::IntraLayer);
            let inter = simulate(&net, &cfg, &DeviceModel::xc7z045(), Mode::InterLayer);
            ensure(
                intra.latency_s <= inter.latency_s * 1.001,
                || format!("inter beat intra: {} vs {}", inter.latency_s, intra.latency_s),
            )
        },
    );
}

#[test]
fn prop_more_pot_means_less_memory_traffic_never_more() {
    // PoT-4 and Fixed-4 pack identically; only the Fixed-8 share moves the
    // weight footprint. Traffic must be monotone in the Fixed-8 share.
    use ilmpq::fpga::sim::synth_masks;
    use ilmpq::model::LayerDesc;
    let layer = LayerDesc::conv("c", 3, 1, 64, 64, 28, 28);
    forall(
        205,
        64,
        |r| {
            let f8a = (r.below(10)) as f64 * 5.0;
            let f8b = (r.below(10)) as f64 * 5.0;
            (f8a.min(f8b), f8a.max(f8b))
        },
        |&(lo8, hi8)| {
            let bytes = |f8: f64| {
                let pot = (100.0 - f8) / 2.0;
                let m = synth_masks("c", 64, Ratio::new(pot, 100.0 - f8 - pot, f8));
                ilmpq::fpga::memory::ddr_bytes(&layer, &m)
            };
            ensure(
                bytes(lo8) <= bytes(hi8) + 1e-9,
                || format!("traffic not monotone in f8: {} vs {}", bytes(lo8), bytes(hi8)),
            )
        },
    );
}

#[test]
fn prop_synth_masks_partition_rows() {
    forall(
        206,
        128,
        |r| (r.range_usize(1, 512), random_ratio(r)),
        |&(rows, ratio)| {
            let m = ilmpq::fpga::sim::synth_masks("l", rows, ratio);
            let (p, f4, f8) = m.counts();
            ensure(p + f4 + f8 == rows, || format!("{p}+{f4}+{f8} != {rows}"))?;
            // No row is both 8-bit and PoT.
            for i in 0..rows {
                ensure(
                    !(m.is8[i] > 0.5 && m.is_pot[i] > 0.5),
                    || format!("row {i} double-assigned"),
                )?;
            }
            Ok(())
        },
    );
}
